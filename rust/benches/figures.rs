//! End-to-end benches, one per paper table/figure: each times a
//! scaled-down version of the experiment that regenerates it (the
//! full-scale CSVs come from `repro experiment <id>`). Reported number:
//! wall time of the complete figure pipeline at 3% cluster scale,
//! 1 repetition.
//!
//! Run: `cargo bench --bench figures` (filter, e.g. `fig3`).

use repro::experiments::{ExpConfig, Harness};
use repro::util::benchkit::{black_box, Bencher};

fn bench_figure(b: &mut Bencher, id: &'static str) {
    let out = std::env::temp_dir().join("repro_bench_figs");
    b.bench(&format!("bench_{id}"), move || {
        let cfg = ExpConfig {
            reps: 1,
            seed: 9,
            scale: 0.03,
            target: 1.0,
            out_dir: out.to_str().unwrap().to_string(),
        };
        // Fresh harness per iteration: measures the uncached pipeline.
        let mut h = Harness::new(cfg);
        black_box(h.run(id).expect(id));
    });
}

fn main() {
    // Macro-benchmark: iterations run a whole figure pipeline (seconds),
    // so keep the sample floor low.
    let mut b = Bencher::with_config(repro::util::benchkit::BenchConfig {
        warmup: std::time::Duration::from_millis(50),
        measure: std::time::Duration::from_secs(3),
        max_samples: 10,
        min_samples: 2,
    });
    println!("== figure pipelines (3% cluster scale, 1 rep) ==");
    for id in [
        "table1", "table2", "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7",
        "fig8", "fig9", "fig10",
    ] {
        bench_figure(&mut b, id);
    }
    b.write_csv("results/bench_figures.csv").ok();
    println!("(csv: results/bench_figures.csv)");
}
