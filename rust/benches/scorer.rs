//! Native scorer vs the AOT-compiled XLA scorer (PJRT), per scheduling
//! decision. Requires `make artifacts`; skips cleanly when artifacts
//! are absent (e.g. a pure-Rust CI job).
//!
//! Run: `cargo bench --bench scorer`

use repro::cluster::ClusterSpec;
use repro::runtime::{artifacts_dir, Runtime};
use repro::sched::{PolicyKind, Scheduler};
use repro::trace::TraceSpec;
use repro::util::benchkit::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new();
    let dir = artifacts_dir().join("small");
    let spec = TraceSpec::default_trace();
    let workload = spec.synthesize(1).workload();

    // A cluster sized to the small artifact (64 node slots).
    let dc = ClusterSpec::paper_scaled(0.04).build();
    let mut sampler = spec.sampler(3);
    println!("== scorer comparison ({} nodes) ==", dc.nodes.len());

    // Native path.
    {
        let mut sched = Scheduler::from_policy(PolicyKind::PwrFgd { alpha: 0.1 });
        let mut tasks = Vec::new();
        for _ in 0..256 {
            tasks.push(sampler.next_task());
        }
        let mut i = 0;
        b.bench("native/pwrfgd-score-decision", || {
            let t = &tasks[i % tasks.len()];
            i += 1;
            black_box(sched.schedule(&dc, &workload, t))
        });
    }

    // XLA path (artifact-gated).
    match Runtime::cpu().and_then(|rt| {
        repro::runtime::scorer::XlaScorer::load(&rt, &dir).map(|s| (rt, s))
    }) {
        Ok((_rt, mut scorer)) => {
            let mut tasks = Vec::new();
            for _ in 0..256 {
                tasks.push(sampler.next_task());
            }
            let mut i = 0;
            // Split out the encode cost from the execute cost.
            b.bench("xla/encode-cluster", || {
                black_box(scorer.encode_cluster(&dc).unwrap())
            });
            scorer.encode_workload(&workload);
            b.bench("xla/score-decision(encode+execute)", || {
                let t = &tasks[i % tasks.len()];
                i += 1;
                scorer.encode_cluster(&dc).unwrap();
                black_box(scorer.score(t, 0.1).unwrap())
            });
        }
        Err(e) => {
            println!("xla scorer skipped (run `make artifacts`): {e}");
        }
    }
    b.write_csv("results/bench_scorer.csv").ok();
    println!("(csv: results/bench_scorer.csv)");
}
