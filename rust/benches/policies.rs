//! Per-decision scheduling latency for every policy, at the paper's
//! full cluster size (1,213 nodes) and a scaled size — the L3 hot path.
//!
//! Run: `cargo bench --bench policies` (filter with a substring arg).

use repro::cluster::ClusterSpec;
use repro::sched::{PolicyKind, Scheduler};
use repro::sim::Simulation;
use repro::trace::TraceSpec;
use repro::util::benchkit::{black_box, Bencher};

/// Pre-load a cluster to ~50% GPU capacity so the benchmark measures
/// mid-inflation decisions (the realistic regime), then time steady
/// scheduling.
fn bench_policy(b: &mut Bencher, policy: PolicyKind, scale: f64, label: &str) {
    let spec = TraceSpec::default_trace();
    let cluster = if scale >= 1.0 {
        ClusterSpec::paper_default()
    } else {
        ClusterSpec::paper_scaled(scale)
    };
    let dc = cluster.build();
    let workload = spec.synthesize(1).workload();
    let sched = Scheduler::from_policy(policy);
    let mut sim = Simulation::with_spec(dc, sched, &spec, workload, 11);
    sim.record_frag = false;
    while sim.capacity_ratio() < 0.5 {
        sim.step();
    }
    b.bench(&format!("{label}/{}", policy.label()), || black_box(sim.step()));
}

/// MIG scenario: slice-granular placements multiply the candidate
/// space (up to 7 starts × 8 GPUs per node), so scoring-throughput
/// regressions on the MIG path show up here.
fn bench_mig_policy(b: &mut Bencher, policy: PolicyKind) {
    let spec = TraceSpec::mig_trace(0.3);
    let dc = ClusterSpec::mig_cluster(32, 8, 4).build();
    let workload = spec.synthesize(1).workload();
    let sched = Scheduler::from_policy(policy);
    let mut sim = Simulation::with_spec(dc, sched, &spec, workload, 11);
    sim.record_frag = false;
    while sim.capacity_ratio() < 0.5 {
        sim.step();
    }
    b.bench(&format!("mig-32-nodes/{}", policy.label()), || black_box(sim.step()));
}

fn main() {
    let mut b = Bencher::new();
    println!("== per-decision scheduling latency (cluster at ~50% load) ==");
    for policy in [
        PolicyKind::Fgd,
        PolicyKind::Pwr,
        PolicyKind::PwrFgd { alpha: 0.1 },
        PolicyKind::BestFit,
        PolicyKind::DotProd,
        PolicyKind::GpuPacking,
        PolicyKind::GpuClustering,
        PolicyKind::FirstFit,
        PolicyKind::Random,
    ] {
        bench_policy(&mut b, policy, 1.0, "full-1213-nodes");
    }
    for policy in [PolicyKind::Fgd, PolicyKind::PwrFgd { alpha: 0.1 }] {
        bench_policy(&mut b, policy, 0.1, "scaled-121-nodes");
    }
    for policy in [
        PolicyKind::MigBestFit,
        PolicyKind::MigSliceFit,
        PolicyKind::MigFgd,
        PolicyKind::MigPwr,
        PolicyKind::MigPwrFgd { alpha: 0.1 },
    ] {
        bench_mig_policy(&mut b, policy);
    }
    b.write_csv("results/bench_policies.csv").ok();
    println!("(csv: results/bench_policies.csv)");
}
