//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored
//! micro-crate provides the subset of `anyhow`'s API the repository
//! uses: a string-backed [`Error`], the [`Result`] alias, the
//! [`Context`] extension trait for `Result` and `Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error messages include the
//! full `source()` chain of the converted error, mirroring anyhow's
//! `{:#}` rendering closely enough for CLI diagnostics.

use std::fmt;

/// A string-backed error with accumulated context.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap with an outer context layer (`context: inner`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert!(e.to_string().starts_with("reading file: "));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn macros() {
        fn fails(n: u32) -> Result<u32> {
            ensure!(n < 10, "too big: {n}");
            if n == 7 {
                bail!("unlucky {n}");
            }
            Ok(n)
        }
        assert_eq!(fails(3).unwrap(), 3);
        assert_eq!(fails(7).unwrap_err().to_string(), "unlucky 7");
        assert_eq!(fails(11).unwrap_err().to_string(), "too big: 11");
        let e = anyhow!("x = {}", 5);
        assert_eq!(e.to_string(), "x = 5");
    }
}
