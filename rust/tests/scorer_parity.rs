//! Integration: the AOT-compiled XLA scorer (L1 Pallas kernel + L2 JAX
//! graph, loaded through PJRT) must take the same scheduling decisions
//! as the native Rust `PwrFgd(α)` scheduler on identical cluster states.
//!
//! Requires `make artifacts`; tests skip (with a notice) when the
//! artifacts are absent so `cargo test` stays runnable in a pure-Rust
//! environment. The whole file is additionally compile-gated on the
//! `xla` cargo feature — without it the PJRT runtime is a stub and
//! there is nothing to check.

#![cfg(feature = "xla")]

use repro::runtime::scorer::parity_check;

fn artifacts_small() -> Option<std::path::PathBuf> {
    let dir = repro::runtime::artifacts_dir().join("small");
    if dir.join("scorer.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        None
    }
}

#[test]
fn xla_scorer_matches_native_alpha_01() {
    let Some(dir) = artifacts_small() else { return };
    let report = parity_check(&dir, 150, 0.1, 42).expect("parity run");
    assert!(report.passed(), "{report}");
    // A solid majority must be exact; the rest are k8s score *ties*
    // (both paths agree on the integer scores, the native scheduler's
    // random tie-break just picked a different equal-score node).
    assert!(
        report.exact_matches * 2 >= report.decisions,
        "too many near-ties: {report}"
    );
}

#[test]
fn xla_scorer_matches_native_pure_pwr() {
    let Some(dir) = artifacts_small() else { return };
    let report = parity_check(&dir, 100, 1.0, 7).expect("parity run");
    assert!(report.passed(), "{report}");
}

#[test]
fn xla_scorer_matches_native_pure_fgd() {
    let Some(dir) = artifacts_small() else { return };
    let report = parity_check(&dir, 100, 0.0, 13).expect("parity run");
    assert!(report.passed(), "{report}");
}

#[test]
fn xla_scorer_handles_saturation() {
    // Push far past capacity: feasibility decisions (including "no
    // node fits") must agree as the cluster saturates.
    let Some(dir) = artifacts_small() else { return };
    let report = parity_check(&dir, 600, 0.1, 99).expect("parity run");
    assert!(report.passed(), "{report}");
    assert!(report.both_infeasible > 0, "saturation never reached: {report}");
}
