//! Dynamic half of the `cacheable-purity` contract (the static half is
//! `repro lint`'s rule over `impl ScorePlugin` blocks): the
//! revision-keyed score cache and the sharded scoring path both assume
//! a plugin whose `cacheable()` is `true` computes scores as a pure
//! function of (cluster state, workload, node generation, task
//! signature). This test pins that claim with exact f64 *bit* equality
//! — first per plugin (scoring order permuted and repeated, as shard
//! threads and cache replays would), then end-to-end through the
//! scheduler (cache on/off × shard counts over a fleet large enough to
//! clear the shard engagement threshold).

use repro::cluster::ClusterSpec;
use repro::frag::PreparedWorkload;
use repro::sched::framework::ClusterCaps;
use repro::sched::profile::builtin_score_plugins;
use repro::sched::{PolicyKind, SchedCtx, Scheduler, ScorePlugin};
use repro::tasks::{GpuDemand, Task, Workload};

/// Every built-in cacheable plugin must return bit-identical scores
/// whatever order (or multiplicity) the per-node score calls arrive in
/// — exactly the freedoms the shard splitter and the cache replay take.
#[test]
fn cacheable_plugins_score_bit_identically_under_permutation() {
    let mut dc = ClusterSpec::tiny(12, 4, 2).build();
    // Load a few nodes so scores actually differ across the fleet.
    for (i, node) in [0usize, 3, 5].into_iter().enumerate() {
        let t = Task::new(100 + i as u64, 2.0, 1024.0, GpuDemand::Frac(0.5));
        let p = dc.nodes[node]
            .candidate_placements(&t)
            .pop()
            .expect("seed placement fits");
        dc.allocate(&t, node, &p);
    }
    let w = Workload::default();
    let pw = PreparedWorkload::new(&w);
    let generations = vec![0u64; dc.nodes.len()];
    let ctx = SchedCtx {
        dc: &dc,
        workload: &w,
        prepared: &pw,
        generations: &generations,
        caps: ClusterCaps::of(&dc),
        gang: None,
    };
    let tasks = [
        Task::new(0, 2.0, 512.0, GpuDemand::Frac(0.5)),
        Task::new(1, 4.0, 1024.0, GpuDemand::Whole(1)),
    ];
    let mut checked = 0;
    for (key, plugin) in builtin_score_plugins() {
        if !plugin.cacheable() {
            // `random` declares itself impure; the cache and the
            // equivalence tests already treat it specially.
            continue;
        }
        checked += 1;
        for task in &tasks {
            let sweep: Vec<(usize, Vec<_>)> = dc
                .nodes
                .iter()
                .map(|n| (n.id, n.candidate_placements(task)))
                .filter(|(_, ps)| !ps.is_empty())
                .collect();
            assert!(!sweep.is_empty(), "{key}: nothing feasible to score");
            // Score the sweep in the given visiting order; report
            // node→bits sorted so orders are comparable.
            let score_in_order = |idxs: &[usize]| -> Vec<(usize, u64)> {
                let mut out: Vec<(usize, u64)> = idxs
                    .iter()
                    .map(|&si| {
                        let (nid, ps) = &sweep[si];
                        (*nid, plugin.score(&ctx, &dc.nodes[*nid], task, ps).to_bits())
                    })
                    .collect();
                out.sort();
                out
            };
            let order: Vec<usize> = (0..sweep.len()).collect();
            let baseline = score_in_order(&order);
            // Repeated (cache replay), reversed and shard-interleaved
            // (two shards visiting even/odd) orders.
            assert_eq!(baseline, score_in_order(&order), "{key}: repeat drifted");
            let reversed: Vec<usize> = order.iter().rev().copied().collect();
            assert_eq!(baseline, score_in_order(&reversed), "{key}: reverse drifted");
            let interleaved: Vec<usize> = order
                .iter()
                .copied()
                .filter(|i| i % 2 == 0)
                .chain(order.iter().copied().filter(|i| i % 2 == 1))
                .collect();
            assert_eq!(baseline, score_in_order(&interleaved), "{key}: shard split drifted");
        }
    }
    assert!(checked >= 8, "expected most built-ins cacheable, saw {checked}");
}

/// End-to-end: a long placement sequence must produce identical
/// decisions (node *and* placement) with the score cache on or off and
/// with any shard count. 128 nodes clears `SHARD_MIN_WORK`, so
/// `shards(4)`/`shards(7)` really run scoped scoring threads.
#[test]
fn decisions_identical_across_cache_and_shard_configs() {
    let w = Workload::default();
    let run = |cache: bool, shards: usize| -> Vec<(usize, String)> {
        let mut dc = ClusterSpec::tiny(128, 2, 0).build();
        let mut s = Scheduler::from_policy(PolicyKind::PwrFgd { alpha: 0.5 });
        s.set_deterministic_ties(true);
        s.set_score_cache(cache);
        s.set_score_shards(shards);
        let mut out = Vec::new();
        for i in 0..48u64 {
            let demand =
                if i % 3 == 0 { GpuDemand::Whole(1) } else { GpuDemand::Frac(0.5) };
            let t = Task::new(i, 2.0, 512.0, demand);
            match s.place(&mut dc, &w, &t) {
                Some(d) => out.push((d.node, format!("{:?}", d.placement))),
                None => out.push((usize::MAX, String::new())),
            }
        }
        out
    };
    let baseline = run(false, 1);
    assert!(
        baseline.iter().any(|(n, _)| *n != usize::MAX),
        "sequence placed nothing — fixture broken"
    );
    assert_eq!(baseline, run(true, 1), "cache-on drifted from naive");
    assert_eq!(baseline, run(false, 4), "shards(4) drifted from naive");
    assert_eq!(baseline, run(true, 7), "cache-on + shards(7) drifted from naive");
}
