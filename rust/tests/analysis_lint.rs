//! Fixture tests for the `repro lint` static-analysis rules
//! (`rust/src/analysis/`): each rule fires exactly once on a
//! seeded-bad in-memory tree, an inline `// lint:allow(<rule>) reason`
//! suppresses it, a reasonless directive is itself a finding — and the
//! real repository tree is clean under every rule.

use repro::analysis::{lint, Finding, RepoTree};

fn findings_for(rule: &str, tree: &RepoTree) -> Vec<Finding> {
    let (_, _, check) = lint::RULES
        .iter()
        .find(|(name, _, _)| *name == rule)
        .unwrap_or_else(|| panic!("rule '{rule}' not registered"));
    check(tree)
}

fn render(findings: &[Finding]) -> String {
    findings.iter().map(|f| format!("  {f}\n")).collect()
}

// ---------------------------------------------------------------- catalog

const OBS_FIXTURE: &str = r##"
pub const METRICS_CATALOG: &[(&str, MetricKind, &str)] = &[
    ("good_key", MetricKind::Counter, "a catalogued counter"),
];
"##;

const OBS_DOC_FIXTURE: &str = r##"
| kind | key | meaning |
|------|-----|---------|
| counter | `good_key` | a catalogued counter |
"##;

fn catalog_tree(caller: &str) -> RepoTree {
    RepoTree::from_files(&[
        ("rust/src/obs/mod.rs", OBS_FIXTURE),
        ("docs/observability.md", OBS_DOC_FIXTURE),
        ("rust/src/sim.rs", caller),
    ])
}

#[test]
fn catalog_drift_fires_once_on_an_uncatalogued_key() {
    let tree = catalog_tree(
        "fn f(r: &Registry) {\n    r.inc(\"good_key\", 1);\n    r.inc(\"rogue_key\", 1);\n}\n",
    );
    let f = findings_for("catalog-drift", &tree);
    assert_eq!(f.len(), 1, "expected exactly one finding:\n{}", render(&f));
    assert!(f[0].message.contains("rogue_key"), "{}", f[0]);
    assert_eq!(f[0].file, "rust/src/sim.rs");
    assert_eq!(f[0].line, 3);
}

#[test]
fn catalog_drift_reports_zombie_and_undocumented_entries() {
    // The catalogued key is never referenced and never documented.
    let tree = RepoTree::from_files(&[
        ("rust/src/obs/mod.rs", OBS_FIXTURE),
        ("docs/observability.md", "| kind | key | meaning |\n"),
        ("rust/src/sim.rs", "fn f() {}\n"),
    ]);
    let f = findings_for("catalog-drift", &tree);
    assert_eq!(f.len(), 2, "zombie + missing doc row:\n{}", render(&f));
    assert!(f.iter().any(|x| x.message.contains("never referenced")));
    assert!(f.iter().any(|x| x.message.contains("missing from the metrics table")));
}

#[test]
fn catalog_drift_allowlist_requires_a_reason() {
    let with_reason = catalog_tree(
        "fn f(r: &Registry) {\n    // lint:allow(catalog-drift) fixture: suppression test\n    r.inc(\"rogue_key\", 1);\n    r.inc(\"good_key\", 1);\n}\n",
    );
    let f = findings_for("catalog-drift", &with_reason);
    assert!(f.is_empty(), "reasoned allowlist must suppress:\n{}", render(&f));

    let reasonless = catalog_tree(
        "fn f(r: &Registry) {\n    // lint:allow(catalog-drift)\n    r.inc(\"rogue_key\", 1);\n    r.inc(\"good_key\", 1);\n}\n",
    );
    let f = findings_for("catalog-drift", &reasonless);
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert!(f[0].message.contains("without a reason"), "{}", f[0]);
}

// ---------------------------------------------------- test registration

const MANIFEST_FIXTURE: &str = r##"
[package]
name = "fixture"

[[test]]
name = "a"
path = "rust/tests/a.rs"
"##;

const CI_FIXTURE: &str = r##"
jobs:
  tier1:
    steps:
      - run: cargo test -q --test a
"##;

#[test]
fn test_registration_fires_once_on_an_orphan_test_file() {
    let tree = RepoTree::from_files(&[
        ("Cargo.toml", MANIFEST_FIXTURE),
        (".github/workflows/ci.yml", CI_FIXTURE),
        ("rust/tests/a.rs", "// registered"),
        ("rust/tests/b.rs", "// orphan"),
    ]);
    let f = findings_for("test-registration", &tree);
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].file, "rust/tests/b.rs");
    assert!(f[0].message.contains("no [[test]] target"), "{}", f[0]);
}

#[test]
fn test_registration_fires_once_on_a_missing_ci_step() {
    let manifest = r##"
[[test]]
name = "a"
path = "rust/tests/a.rs"

[[test]]
name = "b"
path = "rust/tests/b.rs"
"##;
    // The `b` step is commented out, which must not satisfy the rule.
    let ci = "steps:\n  - run: cargo test -q --test a\n  # - run: cargo test -q --test b\n";
    let tree = RepoTree::from_files(&[
        ("Cargo.toml", manifest),
        (".github/workflows/ci.yml", ci),
        ("rust/tests/a.rs", "//"),
        ("rust/tests/b.rs", "//"),
    ]);
    let f = findings_for("test-registration", &tree);
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert!(f[0].message.contains("\"b\" has no `--test b` step"), "{}", f[0]);
}

// ------------------------------------------------------ hot-path hygiene

fn hotpath_tree(framework: &str) -> RepoTree {
    RepoTree::from_files(&[
        ("rust/src/sched/framework.rs", framework),
        ("rust/src/sched/filter.rs", "pub fn ok() {}\n"),
        ("rust/src/sched/bind.rs", "pub fn ok() {}\n"),
        ("rust/src/sched/drs.rs", "pub fn ok() {}\n"),
    ])
}

#[test]
fn hot_path_hygiene_fires_once_on_an_unwrap() {
    let tree = hotpath_tree("pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n");
    let f = findings_for("hot-path-hygiene", &tree);
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!((f[0].file.as_str(), f[0].line), ("rust/src/sched/framework.rs", 2));
}

#[test]
fn hot_path_hygiene_skips_tests_strings_and_comments() {
    let tree = hotpath_tree(concat!(
        "pub fn ok() -> &'static str {\n",
        "    // a comment saying unwrap() and panic! is fine\n",
        "    \"so is unsafe in a string\"\n",
        "}\n",
        "#[cfg(test)]\n",
        "mod tests {\n",
        "    #[test]\n",
        "    fn t() {\n",
        "        Some(1).unwrap();\n",
        "        panic!(\"test-only\");\n",
        "    }\n",
        "}\n",
    ));
    let f = findings_for("hot-path-hygiene", &tree);
    assert!(f.is_empty(), "{}", render(&f));
}

#[test]
fn hot_path_hygiene_allowlist_requires_a_reason() {
    let with_reason = hotpath_tree(concat!(
        "pub fn f(x: Option<u32>) -> u32 {\n",
        "    // lint:allow(hot-path-hygiene) fixture: documented invariant\n",
        "    x.unwrap()\n",
        "}\n",
    ));
    assert!(findings_for("hot-path-hygiene", &with_reason).is_empty());

    let reasonless = hotpath_tree(concat!(
        "pub fn f(x: Option<u32>) -> u32 {\n",
        "    // lint:allow(hot-path-hygiene)\n",
        "    x.unwrap()\n",
        "}\n",
    ));
    let f = findings_for("hot-path-hygiene", &reasonless);
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert!(f[0].message.contains("without a reason"), "{}", f[0]);
}

#[test]
fn hot_path_hygiene_reports_missing_protocol_files() {
    let tree = RepoTree::from_files(&[("rust/src/sched/framework.rs", "pub fn ok() {}\n")]);
    let f = findings_for("hot-path-hygiene", &tree);
    assert_eq!(f.len(), 3, "filter/bind/drs missing:\n{}", render(&f));
    assert!(f.iter().all(|x| x.message.contains("missing")));
}

// ----------------------------------------------------- cacheable purity

#[test]
fn cacheable_purity_fires_once_without_an_override() {
    let tree = RepoTree::from_files(&[(
        "rust/src/sched/policies/p.rs",
        concat!(
            "use std::sync::Mutex;\n",
            "pub struct StatefulPlugin {\n",
            "    cache: Mutex<Vec<f64>>,\n",
            "}\n",
            "impl ScorePlugin for StatefulPlugin {\n",
            "    fn name(&self) -> &'static str {\n",
            "        \"stateful\"\n",
            "    }\n",
            "}\n",
        ),
    )]);
    let f = findings_for("cacheable-purity", &tree);
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert!(f[0].message.contains("StatefulPlugin"), "{}", f[0]);
}

#[test]
fn cacheable_purity_accepts_an_explicit_override_or_a_pure_plugin() {
    let tree = RepoTree::from_files(&[(
        "rust/src/sched/policies/p.rs",
        concat!(
            "use std::sync::atomic::AtomicU64;\n",
            "pub struct StatefulPlugin {\n",
            "    calls: AtomicU64,\n",
            "}\n",
            "impl ScorePlugin for StatefulPlugin {\n",
            "    fn cacheable(&self) -> bool {\n",
            "        false\n",
            "    }\n",
            "}\n",
            "pub struct PurePlugin;\n",
            "impl ScorePlugin for PurePlugin {\n",
            "    fn name(&self) -> &'static str {\n",
            "        \"pure\"\n",
            "    }\n",
            "}\n",
        ),
    )]);
    let f = findings_for("cacheable-purity", &tree);
    assert!(f.is_empty(), "{}", render(&f));
}

// ------------------------------------------------------- dsl-docs drift

const PROFILE_FIXTURE: &str = r##"
const BUILTIN_SCORE: &[Entry] = &[
    ("pwr", "power delta objective", new_pwr),
    ("fgd", "fragmentation delta objective", new_fgd),
];
const BUILTIN_BIND: &[Entry] = &[
    ("bestfit", "tightest candidate placement", new_bf),
];
const BUILTIN_MODULATOR: &[Entry] = &[
    ("loadalpha", "load adaptive alpha", new_la),
];
const BUILTIN_HOOK: &[Entry] = &[
    ("drs", "sleep wake lifecycle", new_drs),
];
const BUILTIN_FILTER: &[Entry] = &[
    ("resources", "cpu mem gpu fit", new_res),
];

fn parse_dsl(name: &str) {
    match name {
        "score" => (),
        "bind" => (),
        "mod" => (),
        "hook" => (),
        "filter" => (),
        _ => (),
    }
}
"##;

const SCHED_DOC_FIXTURE: &str = r##"
## Extension points

| point | phase | built-in keys |
|-------|-------|---------------|
| `score` | scoring | `pwr` |
| `bind` | binding | `bestfit` |
| `weightModulator` | modulate | `loadalpha` |
| `postPlace`/`postFail` | hooks | `drs` |
| `filter` | feasibility | `resources` |

## DSL grammar

```text
policy   := section ('|' section)*
section  := 'score(' list ')' | 'bind(' key ')' | 'mod(' key ')'
          | 'hook(' key ')' | 'filter(' list ')'
```
"##;

#[test]
fn dsl_docs_drift_fires_once_on_an_undocumented_registry_key() {
    // `fgd` is in BUILTIN_SCORE but not in the doc's score row.
    let tree = RepoTree::from_files(&[
        ("rust/src/sched/profile.rs", PROFILE_FIXTURE),
        ("docs/scheduler.md", SCHED_DOC_FIXTURE),
    ]);
    let f = findings_for("dsl-docs-drift", &tree);
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert!(f[0].message.contains("score/fgd"), "{}", f[0]);
}

#[test]
fn dsl_docs_drift_fires_on_a_grammar_only_section() {
    // Grammar documents 'sample(' but parse_dsl has no such arm.
    let doc = SCHED_DOC_FIXTURE.replace(
        "| 'hook(' key ')' | 'filter(' list ')'",
        "| 'hook(' key ')' | 'filter(' list ')' | 'sample(' pct ')'",
    );
    let fixed_profile = PROFILE_FIXTURE.replace(
        "(\"pwr\", \"power delta objective\", new_pwr),\n    (\"fgd\", \"fragmentation delta objective\", new_fgd),",
        "(\"pwr\", \"power delta objective\", new_pwr),",
    );
    let tree = RepoTree::from_files(&[
        ("rust/src/sched/profile.rs", fixed_profile.as_str()),
        ("docs/scheduler.md", doc.as_str()),
    ]);
    let f = findings_for("dsl-docs-drift", &tree);
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert!(f[0].message.contains("'sample('"), "{}", f[0]);
}

// ------------------------------------------------------------ real tree

#[test]
fn rule_table_is_well_formed() {
    assert_eq!(lint::RULES.len(), 5);
    let mut names: Vec<&str> = lint::RULES.iter().map(|(n, _, _)| *n).collect();
    names.sort();
    names.dedup();
    assert_eq!(names.len(), 5, "duplicate rule names");
    assert!(lint::RULES.iter().all(|(_, d, _)| !d.is_empty()));
}

#[test]
fn real_tree_is_clean_under_every_rule() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let tree = RepoTree::load(root).expect("repo tree readable");
    assert!(tree.get("Cargo.toml").is_some(), "tree must include the manifest");
    assert!(
        tree.files.keys().any(|p| p.starts_with("rust/src/")),
        "tree must include the sources"
    );
    let findings = lint::run_all(&tree);
    assert!(findings.is_empty(), "repro lint found:\n{}", render(&findings));
}
