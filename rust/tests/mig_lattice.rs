//! Property tests for the heterogeneous MIG lattice subsystem
//! (hand-rolled generators over the in-repo seeded RNG — the vendored
//! crate set has no `proptest`):
//!
//! * `frag_slices` (the fast bitmask path) equals a brute-force
//!   reference on every mask × profile of both lattices;
//! * random place/release sequences never overlap instance windows and
//!   keep `used + free == lattice slices` — on A100-7g and A30-4g;
//! * the `FragEval` fast path equals the reference `f_node` on random
//!   partition states of both lattices under mixed-lattice workloads;
//! * repartitioner invariants: proactive (threshold) and reactive
//!   (failure) repacks never lose a running instance and never exceed
//!   the migration budget.

use repro::cluster::mig::{
    frag_slices, window_mask, MigGpu, MigLattice, MigProfile,
};
use repro::cluster::node::{Node, Placement, ResourceView};
use repro::cluster::types::{CpuModel, GpuModel};
use repro::cluster::ClusterSpec;
use repro::frag::{f_node, f_node_fast, frag_delta_fast, PreparedWorkload};
use repro::sched::policies::{MigRepartitioner, RepartitionConfig};
use repro::sched::{PolicyKind, Scheduler};
use repro::tasks::{GpuDemand, Task, TaskClass, Workload};
use repro::util::rng::Rng;

/// Brute-force reference for [`frag_slices`]: a free slice is a
/// fragment iff no legal, non-overlapping placement window of the
/// profile contains it.
fn frag_slices_reference(mask: u8, profile: MigProfile) -> u8 {
    let slices = profile.lattice().slices();
    let mut frags = 0u8;
    for s in 0..slices {
        if mask & (1 << s) != 0 {
            continue; // occupied, not a fragment candidate
        }
        let coverable = profile.legal_starts().iter().any(|&start| {
            let w = window_mask(profile, start);
            mask & w == 0 && w & (1 << s) != 0
        });
        if !coverable {
            frags += 1;
        }
    }
    frags
}

/// Exhaustive: the fast path equals the reference on *every* mask of
/// both lattices (the A100 space is only 2^7).
#[test]
fn frag_slices_fast_path_equals_reference_exhaustively() {
    for lat in MigLattice::ALL {
        for mask in 0..=lat.full_mask() {
            for &p in lat.profiles() {
                assert_eq!(
                    frag_slices(mask, p),
                    frag_slices_reference(mask, p),
                    "lattice {lat} mask {mask:#b} profile {p}"
                );
            }
        }
    }
}

/// Random place/release sequences on a single GPU of an arbitrary
/// lattice: instance windows never overlap, the mask is always their
/// union, and `used + free == lattice slices`.
#[test]
fn random_place_release_never_overlaps_and_conserves_slices() {
    let mut rng = Rng::new(0x1A771CE);
    for trial in 0..300 {
        let lat = *rng.choice(&MigLattice::ALL);
        let mut g = MigGpu::with_lattice(lat);
        for step in 0..80 {
            if !g.instances.is_empty() && rng.bernoulli(0.4) {
                let inst = g.instances[rng.below(g.instances.len())];
                assert!(g.release(inst.profile, Some(inst.start)));
            } else {
                let p = *rng.choice(lat.profiles());
                let starts = g.free_starts(p);
                if starts.is_empty() {
                    assert_eq!(g.can_place(p), None, "free_starts/can_place disagree");
                    continue;
                }
                let s = starts[rng.below(starts.len())];
                assert!(g.place(p, s), "trial {trial} step {step}: place {p}@{s}");
            }
            // Windows pairwise disjoint and union == mask.
            let mut union = 0u8;
            for inst in &g.instances {
                let w = window_mask(inst.profile, inst.start);
                assert_eq!(union & w, 0, "trial {trial} step {step}: overlap");
                union |= w;
            }
            assert_eq!(union, g.mask, "mask drifted from instance windows");
            assert_eq!(union & !lat.full_mask(), 0, "mask escaped the lattice");
            assert_eq!(g.used_slices() + g.free_slices(), lat.slices());
        }
    }
}

fn mixed_workload(rng: &mut Rng) -> Workload {
    let mut classes = Vec::new();
    for _ in 0..rng.range(1, 10) {
        let gpu = match rng.below(4) {
            0 => GpuDemand::Zero,
            1 => GpuDemand::Frac(*rng.choice(&[0.25, 0.5, 0.75])),
            2 => GpuDemand::Whole(*rng.choice(&[1u32, 2])),
            _ => GpuDemand::Mig(*rng.choice(&MigProfile::ALL)),
        };
        classes.push(TaskClass {
            cpu: rng.range_f64(0.0, 64.0),
            mem: rng.range_f64(0.0, 300_000.0),
            gpu,
            gpu_model: if rng.bernoulli(0.2) {
                Some(*rng.choice(&[GpuModel::G3, GpuModel::A30, GpuModel::T4]))
            } else {
                None
            },
            pop: rng.range_f64(0.01, 1.0),
        });
    }
    Workload::new(classes)
}

/// The node-level fragmentation fast path equals the reference on
/// random partition states of both lattices, under workloads mixing
/// both lattices' profiles with fractional/whole/CPU classes — current
/// state and every hypothetical slice placement.
#[test]
fn f_node_fast_path_equals_reference_on_both_lattices() {
    let mut rng = Rng::new(0xA30A100);
    for trial in 0..200 {
        let (model, lat) = if trial % 2 == 0 {
            (GpuModel::G3, MigLattice::A100)
        } else {
            (GpuModel::A30, MigLattice::A30)
        };
        let n_gpus = rng.range(1, 5);
        let mut n = Node::new(0, CpuModel::XeonE5_2682V4, Some(model), 128.0, 786_432.0, n_gpus);
        n.enable_mig();
        n.cpu_alloc = rng.range_f64(0.0, 100.0);
        // Random legal partition per GPU.
        for j in 0..n_gpus {
            for _ in 0..rng.below(5) {
                let p = *rng.choice(lat.profiles());
                let migs = n.mig.as_mut().unwrap();
                if let Some(s) = migs[j].can_place(p) {
                    migs[j].place(p, s);
                    n.gpu_alloc[j] = migs[j].alloc_fraction();
                }
            }
        }
        let w = mixed_workload(&mut rng);
        let pw = PreparedWorkload::new(&w);
        let slow = f_node(&n, &w);
        let fast = f_node_fast(&n, &pw);
        assert!(
            (slow - fast).abs() < 1e-9,
            "trial {trial} ({lat}): {slow} vs {fast}"
        );
        // Hypothetical placements of a random profile of this lattice.
        let task = Task::new(
            trial,
            rng.range_f64(0.0, 16.0),
            rng.range_f64(0.0, 50_000.0),
            GpuDemand::Mig(*rng.choice(lat.profiles())),
        );
        for p in n.candidate_placements(&task) {
            let slow_d = {
                let h = n.hypothetical(&task, &p);
                f_node(&h, &w) - slow
            };
            let fast_d = frag_delta_fast(&n, &task, &p, &pw, fast);
            assert!(
                (slow_d - fast_d).abs() < 1e-9,
                "trial {trial} ({lat}) {p:?}: {slow_d} vs {fast_d}"
            );
        }
        // Foreign-lattice demands never fit this node.
        let other = if lat == MigLattice::A100 { MigLattice::A30 } else { MigLattice::A100 };
        let foreign = Task::new(0, 1.0, 0.0, GpuDemand::Mig(other.profiles()[0]));
        assert!(!n.can_fit(&foreign));
        assert!(n.candidate_placements(&foreign).is_empty());
    }
}

/// Repartitioner invariants under random churn on a heterogeneous
/// fleet: reactive and proactive repacks never lose (or duplicate) a
/// running instance, the shared migration budget is never exceeded,
/// and the node's `gpu_alloc` mirror stays exact.
#[test]
fn repartitioner_never_loses_instances_and_respects_budget() {
    let mut rng = Rng::new(0xDEF7A6);
    for trial in 0..8 {
        let budget = [20u64, 60, u64::MAX][trial % 3];
        let cfg = RepartitionConfig {
            budget_slices: budget,
            frag_threshold: 0.5,
            ..Default::default()
        };
        let mut rp = MigRepartitioner::new(cfg);
        let mut dc = ClusterSpec::mig_het_cluster(2, 2, 2, 0).build();
        let mut sched = Scheduler::from_policy(PolicyKind::MigFgd);
        let w = Workload::default();
        let mut live: Vec<(Task, usize, Placement)> = Vec::new();
        for step in 0..400 {
            if !live.is_empty() && rng.bernoulli(0.45) {
                let (task, node, placement) = live.swap_remove(rng.below(live.len()));
                dc.deallocate(&task, node, &placement);
                sched.notify_node_changed(node);
                if rp.defrag_node_if_fragmented(&mut dc, node) {
                    sched.notify_node_changed(node);
                }
            } else {
                let p = *rng.choice(&MigProfile::ALL);
                let task = Task::new(step + trial as u64 * 1000, 2.0, 512.0, GpuDemand::Mig(p));
                // The postFail protocol, driven by hand so `rp` stays
                // external and inspectable between steps (the framework
                // equivalent is `Scheduler::place` with a repartition
                // hook attached).
                let mut d = sched.schedule(&dc, &w, &task);
                if d.is_none() {
                    if let Some(node_id) = rp.try_make_room(&mut dc, &task) {
                        sched.notify_node_changed(node_id);
                        d = sched.schedule(&dc, &w, &task);
                    }
                }
                if let Some(d) = d {
                    dc.allocate(&task, d.node, &d.placement);
                    sched.notify_node_changed(d.node);
                    if rp.defrag_node_if_fragmented(&mut dc, d.node) {
                        sched.notify_node_changed(d.node);
                    }
                    live.push((task, d.node, d.placement));
                }
            }
            // --- Invariants, every step. ---
            // No instance lost or duplicated: the cluster-wide instance
            // count equals the live MIG task count, and per-profile
            // multisets match.
            let mut resident: Vec<MigProfile> = Vec::new();
            for node in &dc.nodes {
                let migs = node.mig.as_ref().unwrap();
                for (g, mg) in migs.iter().enumerate() {
                    // Window disjointness survives repacks.
                    let mut union = 0u8;
                    for inst in &mg.instances {
                        let w = window_mask(inst.profile, inst.start);
                        assert_eq!(union & w, 0, "trial {trial} step {step}: overlap");
                        union |= w;
                        resident.push(inst.profile);
                    }
                    assert_eq!(union, mg.mask);
                    assert!(
                        (node.gpu_alloc[g] - mg.alloc_fraction()).abs() < 1e-12,
                        "gpu_alloc mirror drift"
                    );
                }
            }
            let mut expected: Vec<MigProfile> =
                live.iter()
                    .map(|(t, _, _)| match t.gpu {
                        GpuDemand::Mig(p) => p,
                        _ => unreachable!(),
                    })
                    .collect();
            resident.sort();
            expected.sort();
            assert_eq!(resident, expected, "trial {trial} step {step}: instances lost");
            // Budget cap is a hard invariant of both triggers.
            assert!(
                rp.stats.migrated_slices <= budget,
                "trial {trial}: migrated {} > budget {budget}",
                rp.stats.migrated_slices
            );
        }
        // The proactive trigger actually exercises on unbounded budgets.
        if budget == u64::MAX {
            assert!(
                rp.stats.proactive_repartitions + rp.stats.repartitions > 0,
                "trial {trial}: repartitioner never fired"
            );
        }
    }
}

/// Cross-lattice isolation end to end: a mixed fleet schedules both
/// lattices' demands, and every bound placement lands on a node of the
/// matching lattice.
#[test]
fn mixed_fleet_placements_respect_lattices() {
    let mut dc = ClusterSpec::mig_het_cluster(2, 2, 4, 1).build();
    let spec = repro::trace::TraceSpec::mig_het_trace(0.3, 0.5);
    let workload = spec.synthesize(3).workload();
    let mut sched = Scheduler::from_policy(PolicyKind::MigPwrFgd { alpha: 0.1 });
    let mut sampler = spec.sampler(17);
    let mut placed = [0u64; 2];
    for _ in 0..400 {
        let task = sampler.next_task();
        if let Some(d) = sched.schedule(&dc, &workload, &task) {
            let node = &dc.nodes[d.node];
            assert!(node.placement_fits(&task, &d.placement));
            if let GpuDemand::Mig(p) = task.gpu {
                assert_eq!(
                    node.mig_lattice(),
                    Some(p.lattice()),
                    "profile {p} bound to a foreign-lattice node"
                );
                placed[p.lattice().index()] += 1;
            }
            dc.allocate(&task, d.node, &d.placement);
            sched.notify_node_changed(d.node);
        }
    }
    assert!(placed[0] > 0, "no A100 placements");
    assert!(placed[1] > 0, "no A30 placements");
}
