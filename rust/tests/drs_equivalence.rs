//! DRS equivalence + power-state property suite.
//!
//! The DRS subsystem (`rust/src/sched/drs.rs`, `docs/power.md`) must
//! be invisible when disabled: a scheduler carrying a `drs` hook with
//! `idle_timeout = ∞` (plus the `drs` filter now in the default chain
//! and the state-aware power sums) has to produce **bit-identical**
//! fixed-seed runs against a scheduler without the hook — across
//! policies × trace families × seeds, in both simulation loops
//! (inflation and steady-state churn).
//!
//! The suite also pins the active side: under finite timeouts nodes
//! actually drain, sleep and wake; a `Draining`/`Asleep`/`Waking` node
//! never receives a placement; the sleep/wake ledger conserves
//! (`sleeps = wakes + currently asleep`, transition energy =
//! `sleeps·sleep_j + wakes·wake_j` exactly, standby never
//! double-counted on top of idle watts); and the `ext-drs` acceptance
//! criterion in miniature — PWR⊕FGD+consolidate+DRS beats plain
//! PWR⊕FGD on EOPC over a diurnal trace without giving up more than
//! 2 GRAR points.

use repro::cluster::node::PowerState;
use repro::cluster::ClusterSpec;
use repro::power;
use repro::sched::{DrsConfig, DrsHook, SchedulerProfile};
use repro::sim::events::{SteadyConfig, SteadySim};
use repro::sim::{RunResult, Simulation};
use repro::tasks::{GpuDemand, Task};
use repro::trace::TraceSpec;

/// Attach a `drs` hook with the given config (None = no hook at all).
fn run_inflation(
    policy: &str,
    drs: Option<DrsConfig>,
    cluster: &ClusterSpec,
    trace: &TraceSpec,
    seed: u64,
    target: f64,
) -> RunResult {
    let mut sched = SchedulerProfile::parse(policy).unwrap().build().unwrap();
    if let Some(cfg) = drs {
        sched.add_post_hook(Box::new(DrsHook::new(cfg)));
    }
    let dc = cluster.build();
    let workload = trace.synthesize(seed ^ 0x57AB1E).workload();
    let mut sim = Simulation::with_spec(dc, sched, trace, workload, seed);
    sim.record_frag = false;
    sim.run_inflation(target)
}

fn assert_bit_identical(what: &str, a: &RunResult, b: &RunResult) {
    assert_eq!(a.submitted, b.submitted, "{what}: submitted diverged");
    assert_eq!(a.scheduled, b.scheduled, "{what}: scheduled diverged");
    assert_eq!(a.failed, b.failed, "{what}: failed diverged");
    assert_eq!(
        a.allocated_gpu_units.to_bits(),
        b.allocated_gpu_units.to_bits(),
        "{what}: allocated units diverged"
    );
    assert_eq!(
        a.final_eopc().to_bits(),
        b.final_eopc().to_bits(),
        "{what}: final EOPC diverged ({} vs {})",
        a.final_eopc(),
        b.final_eopc()
    );
    assert_eq!(
        a.final_grar().to_bits(),
        b.final_grar().to_bits(),
        "{what}: final GRAR diverged"
    );
}

/// timeout=∞ is the legacy mode: bit-identical inflation runs with and
/// without the hook, across policies × traces × seeds.
#[test]
fn infinite_timeout_is_bit_identical_in_inflation() {
    let cluster = ClusterSpec::tiny(6, 4, 1);
    let traces = [
        TraceSpec::default_trace(),
        TraceSpec::sharing_gpu(1.0),
        TraceSpec::multi_gpu(0.2),
    ];
    // A nonzero wake latency must be irrelevant while nothing sleeps.
    let inert = DrsConfig::with_timeout(f64::INFINITY, 50);
    for policy in ["fgd", "pwrfgd:0.1", "bestfit", "firstfit", "random"] {
        for trace in &traces {
            for seed in [1u64, 42] {
                let what = format!("{policy}/{}/seed{seed}", trace.name);
                let base = run_inflation(policy, None, &cluster, trace, seed, 0.7);
                let with = run_inflation(policy, Some(inert), &cluster, trace, seed, 0.7);
                assert!(base.submitted > 0, "{what}: empty run");
                assert_bit_identical(&what, &base, &with);
                assert_eq!(with.drs_sleeps, 0, "{what}: slept with timeout=∞");
                assert_eq!(with.drs_wakes, 0, "{what}: woke with timeout=∞");
            }
        }
    }
}

/// The same pin on a MIG fleet (the `drs` filter sits after the MIG
/// plugins in the default chain and must not disturb slice placement).
#[test]
fn infinite_timeout_is_bit_identical_on_mig() {
    let cluster = ClusterSpec::mig_het_cluster(3, 2, 4, 1);
    let trace = TraceSpec::mig_het_trace(0.3, 0.4);
    let inert = DrsConfig::with_timeout(f64::INFINITY, 10);
    for policy in ["mig-fgd", "mig-pwrfgd:0.1"] {
        let base = run_inflation(policy, None, &cluster, &trace, 11, 0.8);
        let with = run_inflation(policy, Some(inert), &cluster, &trace, 11, 0.8);
        assert!(base.scheduled > 0, "{policy}: scheduled nothing");
        assert_bit_identical(policy, &base, &with);
    }
}

/// timeout=∞ under churn: the steady-state loop (arrivals +
/// departures through `Scheduler::place`/`release`, the second loop of
/// the equivalence property) must agree bit for bit too.
#[test]
fn infinite_timeout_is_bit_identical_under_churn() {
    let cfg = SteadyConfig {
        mean_interarrival_s: 1.0,
        mean_duration_s: 250.0,
        horizon_s: 2_500.0,
        sample_every_s: 50.0,
        seed: 9,
    };
    let cluster = ClusterSpec::tiny(8, 4, 2);
    let trace = TraceSpec::default_trace();
    let run = |drs: Option<DrsConfig>| {
        let mut sched = SchedulerProfile::parse("pwrfgd:0.1").unwrap().build().unwrap();
        if let Some(c) = drs {
            sched.add_post_hook(Box::new(DrsHook::new(c)));
        }
        let mut sim = SteadySim::new(cluster.build(), sched, &trace, &cfg);
        sim.run(&cfg)
    };
    let a = run(None);
    let b = run(Some(DrsConfig::with_timeout(f64::INFINITY, 100)));
    assert!(a.arrivals > 1_000, "arrivals {}", a.arrivals);
    assert_eq!(a.arrivals, b.arrivals);
    assert_eq!(a.scheduled, b.scheduled);
    assert_eq!(a.failed, b.failed);
    assert_eq!(a.departures, b.departures);
    assert_eq!(
        a.steady_eopc_w.to_bits(),
        b.steady_eopc_w.to_bits(),
        "steady EOPC diverged"
    );
    assert_eq!(b.drs_sleeps, 0);
    assert_eq!(b.mean_asleep_nodes, 0.0);
}

/// Power-state transition properties under random churn, driven
/// through the real `place`/`release` protocol:
/// * a placement never lands on a `Draining`/`Asleep`/`Waking` node,
/// * the sleep/wake ledger conserves at every step
///   (`sleeps = wakes + |Asleep ∪ Waking|`, `wakes ≤ sleeps`),
/// * observed datacenter power decomposes into exactly one of
///   standby/Eq. 1-2 per node (never negative, never double-counted),
/// * transition energy is exactly `sleeps·sleep_j + wakes·wake_j`.
#[test]
fn power_state_invariants_under_random_churn() {
    let mut dc = ClusterSpec::tiny(8, 2, 1).build();
    let profile = SchedulerProfile::parse(
        "score(pwr=0.1,fgd=0.7,consolidate=0.2)|bind(weighted:0.1)|hook(drs:5:3:25:100)",
    )
    .unwrap();
    let mut sched = profile.build().unwrap();
    let spec = TraceSpec::default_trace();
    let workload = spec.synthesize(3).workload();
    let mut sampler = spec.sampler(7);
    let mut resident: Vec<(Task, usize, repro::cluster::Placement)> = Vec::new();
    let mut placed_total = 0u64;
    for step in 0..3_000usize {
        if step % 5 == 3 && !resident.is_empty() {
            // Departure: free a resident task (deterministic pick).
            let (t, n, p) = resident.remove(step % resident.len());
            sched.release(&mut dc, &t, n, &p);
        } else {
            let task = sampler.next_task();
            if let Some(d) = sched.place(&mut dc, &workload, &task) {
                assert_eq!(
                    dc.nodes[d.node].power_state,
                    PowerState::Active,
                    "step {step}: placement on a non-Active node"
                );
                resident.push((task, d.node, d.placement));
                placed_total += 1;
            }
        }
        // Ledger conservation at every step.
        let asleep = dc
            .nodes
            .iter()
            .filter(|n| n.power_state == PowerState::Asleep)
            .count() as u64;
        let waking = dc
            .nodes
            .iter()
            .filter(|n| matches!(n.power_state, PowerState::Waking { .. }))
            .count() as u64;
        let sleeps = sched.hook_counter("drs_sleeps");
        let wakes = sched.hook_counter("drs_wakes");
        assert!(wakes <= sleeps, "step {step}: woke more than ever slept");
        assert_eq!(
            sleeps,
            wakes + asleep,
            "step {step}: sleep/wake ledger out of balance (waking={waking})"
        );
        // Observed power decomposes node-by-node, exactly once each.
        let p_obs = power::p_datacenter(&dc);
        let expect: f64 = dc.nodes.iter().map(power::p_node_observed).sum();
        assert!((p_obs - expect).abs() < 1e-6, "step {step}: power decomposition");
        let p_full: f64 = dc.nodes.iter().map(|n| power::p_node(n)).sum();
        assert!(p_obs >= asleep as f64 * power::NODE_STANDBY_W - 1e-9);
        assert!(p_obs <= p_full + 1e-9, "step {step}: sleeping increased power");
    }
    assert!(placed_total > 300, "churn placed too little: {placed_total}");
    let sleeps = sched.hook_counter("drs_sleeps");
    let wakes = sched.hook_counter("drs_wakes");
    assert!(sleeps > 0, "aggressive timeout never slept a node");
    // Exact transition-energy ledger (integer joule costs).
    assert_eq!(
        sched.hook_counter("drs_transition_j"),
        sleeps * 25 + wakes * 100,
        "transition energy double-counted or lost"
    );
}

/// Non-Active nodes are excluded by the default filter chain in plain
/// scheduling too (no hook attached — states pinned by hand).
#[test]
fn draining_and_sleeping_nodes_never_receive_placements() {
    use repro::sched::{PolicyKind, Scheduler};
    use repro::tasks::Workload;
    let mut dc = ClusterSpec::tiny(2, 2, 0).build();
    let w = Workload::default();
    let mut sched = Scheduler::from_policy(PolicyKind::FirstFit);
    let t = Task::new(0, 1.0, 0.0, GpuDemand::Whole(1));
    dc.nodes[0].power_state = PowerState::Draining;
    let d = sched.schedule(&dc, &w, &t).expect("node 1 is awake");
    assert_eq!(d.node, 1, "draining node selected");
    for state in [
        PowerState::Asleep,
        PowerState::Draining,
        PowerState::Waking { ready_at: 10 },
    ] {
        dc.nodes[1].power_state = state;
        assert!(
            sched.schedule(&dc, &w, &t).is_none(),
            "placed onto {state:?} with the whole fleet unavailable"
        );
    }
    dc.nodes[1].power_state = PowerState::Active;
    assert!(sched.schedule(&dc, &w, &t).is_some());
}

/// The `ext-drs` acceptance criterion in miniature: on a diurnal trace
/// the DRS composition must achieve a lower steady-state EOPC than
/// plain PWR⊕FGD at equal offered load, sleep real nodes, and not
/// degrade GRAR by more than 2 points.
#[test]
fn drs_saves_power_on_diurnal_load_without_grar_collapse() {
    let cfg = SteadyConfig {
        mean_interarrival_s: 1.0,
        mean_duration_s: 40.0,
        horizon_s: 4_000.0,
        sample_every_s: 50.0,
        seed: 11,
    };
    let cluster = ClusterSpec::tiny(16, 4, 2);
    let trace = TraceSpec::diurnal_with_period(0.6, 2_000.0);
    let run = |policy: &str| {
        let sched = SchedulerProfile::parse(policy).unwrap().build().unwrap();
        let mut sim = SteadySim::new(cluster.build(), sched, &trace, &cfg);
        sim.run(&cfg)
    };
    let base = run("pwrfgd:0.1");
    let drs = run("score(pwr=0.1,fgd=0.7,consolidate=0.2)|bind(weighted:0.1)|hook(drs:80:5)");
    assert!(drs.drs_sleeps > 0, "no node ever slept");
    assert!(drs.mean_asleep_nodes > 0.0, "steady state kept nothing asleep");
    assert!(
        drs.steady_eopc_w < base.steady_eopc_w,
        "DRS did not save power: {} vs base {}",
        drs.steady_eopc_w,
        base.steady_eopc_w
    );
    assert!(
        drs.final_grar() >= base.final_grar() - 0.02,
        "GRAR degraded by more than 2 points: {} vs base {}",
        drs.final_grar(),
        base.final_grar()
    );
}

/// Wake-on-demand end to end: drive the fleet asleep through a lull,
/// then push demand and watch sleepers come back and host it.
#[test]
fn demand_pressure_wakes_sleepers_end_to_end() {
    use repro::tasks::Workload;
    let mut dc = ClusterSpec::tiny(4, 2, 0).build();
    let profile = SchedulerProfile::parse(
        "score(pwr=0.1,fgd=0.9)|bind(weighted:0.1)|hook(drs:3:2)",
    )
    .unwrap();
    let mut sched = profile.build().unwrap();
    let w = Workload::default();
    // A lull: cycle short CPU-only tasks to tick the clock while the
    // GPUs sit idle, until untouched nodes drain and sleep.
    for i in 0..40u64 {
        let t = Task::new(i, 1.0, 0.0, GpuDemand::Zero);
        if let Some(d) = sched.place(&mut dc, &w, &t) {
            sched.release(&mut dc, &t, d.node, &d.placement);
        }
    }
    assert!(
        dc.nodes.iter().any(|n| n.power_state == PowerState::Asleep),
        "lull never slept a node"
    );
    // Demand pressure: whole-GPU tasks. Failures trigger wakes; after
    // the 2-tick boot, capacity returns and placements succeed.
    let mut scheduled = 0;
    for i in 100..140u64 {
        let t = Task::new(i, 1.0, 0.0, GpuDemand::Whole(1));
        if sched.place(&mut dc, &w, &t).is_some() {
            scheduled += 1;
        }
    }
    assert!(sched.hook_counter("drs_wakes") > 0, "pressure never woke a sleeper");
    assert!(scheduled >= 4, "woken capacity never hosted demand: {scheduled}");
}
