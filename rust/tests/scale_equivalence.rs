//! Scale-out fast-path equivalence suite.
//!
//! The scoring fast path (revision-keyed per-plugin score cache,
//! `sample(<pct>)` candidate sampling, `shards(<n>)` parallel scoring —
//! see the `rust/src/sched/framework.rs` module docs) must be invisible
//! whenever the knobs keep the exhaustive sweep: cache on ≡ cache off,
//! sampling at 100% ≡ the naive loop, and any shard count ≡ sequential
//! scoring (pure plugins compute the same IEEE-754 values on any
//! thread; the impure `random` plugin is never cached or sharded) —
//! **bit-identical** fixed-seed runs across policies × trace families
//! × seeds, in both simulation loops (inflation and steady-state
//! churn), including DRS power-state churn on a diurnal trace.
//!
//! The suite also sanity-pins the lossy side of the sampling knob: a
//! truncated sweep (`sample(25)` on a fleet larger than the 100-node
//! feasibility floor) still serves feasible demand and reports itself
//! through the `sched_sampled_sweeps` counter.

use repro::cluster::ClusterSpec;
use repro::sched::{Scheduler, SchedulerProfile};
use repro::sim::events::{SteadyConfig, SteadySim};
use repro::sim::{RunResult, Simulation};
use repro::trace::TraceSpec;

/// Fast-path knob settings for one run.
#[derive(Clone, Copy)]
struct Knobs {
    cache: bool,
    shards: usize,
    sample_pct: u32,
}

/// The pre-fast-path loop: no cache, sequential scoring, exhaustive
/// sweep. Every equivalence test measures against this baseline.
const NAIVE: Knobs = Knobs { cache: false, shards: 1, sample_pct: 100 };

/// Fast-path variants that must stay bit-identical to [`NAIVE`]: each
/// knob alone, then all together.
const EXACT_VARIANTS: [Knobs; 3] = [
    Knobs { cache: true, shards: 1, sample_pct: 100 },
    Knobs { cache: false, shards: 4, sample_pct: 100 },
    Knobs { cache: true, shards: 4, sample_pct: 100 },
];

fn build(policy: &str, k: Knobs) -> Scheduler {
    let mut sched = SchedulerProfile::parse(policy).unwrap().build().unwrap();
    sched.set_score_cache(k.cache);
    sched.set_score_shards(k.shards);
    sched.set_sample_pct(k.sample_pct);
    sched
}

fn run_inflation(
    policy: &str,
    k: Knobs,
    cluster: &ClusterSpec,
    trace: &TraceSpec,
    seed: u64,
    target: f64,
) -> RunResult {
    let sched = build(policy, k);
    let dc = cluster.build();
    let workload = trace.synthesize(seed ^ 0x57AB1E).workload();
    let mut sim = Simulation::with_spec(dc, sched, trace, workload, seed);
    sim.record_frag = false;
    sim.run_inflation(target)
}

fn assert_bit_identical(what: &str, a: &RunResult, b: &RunResult) {
    assert_eq!(a.submitted, b.submitted, "{what}: submitted diverged");
    assert_eq!(a.scheduled, b.scheduled, "{what}: scheduled diverged");
    assert_eq!(a.failed, b.failed, "{what}: failed diverged");
    assert_eq!(
        a.allocated_gpu_units.to_bits(),
        b.allocated_gpu_units.to_bits(),
        "{what}: allocated units diverged"
    );
    assert_eq!(
        a.final_eopc().to_bits(),
        b.final_eopc().to_bits(),
        "{what}: final EOPC diverged ({} vs {})",
        a.final_eopc(),
        b.final_eopc()
    );
    assert_eq!(
        a.final_grar().to_bits(),
        b.final_grar().to_bits(),
        "{what}: final GRAR diverged"
    );
}

/// Cache / shards at sampling=100%: bit-identical inflation runs across
/// policies × traces × seeds. `random` rides along to pin that the
/// non-cacheable plugin is bypassed, not frozen, by the cache.
#[test]
fn fast_path_is_bit_identical_in_inflation() {
    let cluster = ClusterSpec::tiny(6, 4, 1);
    let traces = [
        TraceSpec::default_trace(),
        TraceSpec::sharing_gpu(1.0),
        TraceSpec::multi_gpu(0.2),
    ];
    for policy in ["fgd", "pwrfgd:0.1", "bestfit", "random"] {
        for trace in &traces {
            for seed in [1u64, 42] {
                let what = format!("{policy}/{}/seed{seed}", trace.name);
                let base = run_inflation(policy, NAIVE, &cluster, trace, seed, 0.7);
                assert!(base.submitted > 0, "{what}: empty run");
                for (vi, k) in EXACT_VARIANTS.iter().enumerate() {
                    let with = run_inflation(policy, *k, &cluster, trace, seed, 0.7);
                    assert_bit_identical(&format!("{what}/variant{vi}"), &base, &with);
                }
            }
        }
    }
}

/// The same pin on a MIG fleet: the score-cache key must separate MIG
/// profile demands (`TaskSig` covers the lattice-indexed variants) and
/// the slice-aware plugins must shard cleanly.
#[test]
fn fast_path_is_bit_identical_on_mig() {
    let cluster = ClusterSpec::mig_het_cluster(3, 2, 4, 1);
    let trace = TraceSpec::mig_het_trace(0.3, 0.4);
    for policy in ["mig-fgd", "mig-pwrfgd:0.1"] {
        let base = run_inflation(policy, NAIVE, &cluster, &trace, 11, 0.8);
        assert!(base.scheduled > 0, "{policy}: scheduled nothing");
        for (vi, k) in EXACT_VARIANTS.iter().enumerate() {
            let with = run_inflation(policy, *k, &cluster, &trace, 11, 0.8);
            assert_bit_identical(&format!("{policy}/variant{vi}"), &base, &with);
        }
    }
}

/// The second simulation loop: steady-state churn through the
/// `place`/`release` protocol must agree bit for bit too (releases
/// invalidate via generation bumps; the cache must track them).
#[test]
fn fast_path_is_bit_identical_under_churn() {
    let cfg = SteadyConfig {
        mean_interarrival_s: 1.0,
        mean_duration_s: 250.0,
        horizon_s: 2_500.0,
        sample_every_s: 50.0,
        seed: 9,
    };
    let cluster = ClusterSpec::tiny(8, 4, 2);
    let trace = TraceSpec::default_trace();
    let run = |k: Knobs| {
        let sched = build("pwrfgd:0.1", k);
        let mut sim = SteadySim::new(cluster.build(), sched, &trace, &cfg);
        sim.run(&cfg)
    };
    let a = run(NAIVE);
    assert!(a.arrivals > 1_000, "arrivals {}", a.arrivals);
    for (vi, k) in EXACT_VARIANTS.iter().enumerate() {
        let b = run(*k);
        assert_eq!(a.arrivals, b.arrivals, "variant{vi}");
        assert_eq!(a.scheduled, b.scheduled, "variant{vi}");
        assert_eq!(a.failed, b.failed, "variant{vi}");
        assert_eq!(a.departures, b.departures, "variant{vi}");
        assert_eq!(
            a.steady_eopc_w.to_bits(),
            b.steady_eopc_w.to_bits(),
            "variant{vi}: steady EOPC diverged"
        );
    }
}

/// The hard case: DRS diurnal churn. Power-state transitions
/// (drain/sleep/wake) invalidate scored nodes mid-run and the
/// `consolidate` plugin reads the very state that changes; the cache
/// and the shard merge must still be invisible.
#[test]
fn fast_path_is_bit_identical_with_drs_diurnal_churn() {
    let cfg = SteadyConfig {
        mean_interarrival_s: 1.0,
        mean_duration_s: 40.0,
        horizon_s: 4_000.0,
        sample_every_s: 50.0,
        seed: 11,
    };
    let cluster = ClusterSpec::tiny(16, 4, 2);
    let trace = TraceSpec::diurnal_with_period(0.6, 2_000.0);
    let policy = "score(pwr=0.1,fgd=0.7,consolidate=0.2)|bind(weighted:0.1)|hook(drs:80:5)";
    let run = |k: Knobs| {
        let sched = build(policy, k);
        let mut sim = SteadySim::new(cluster.build(), sched, &trace, &cfg);
        sim.run(&cfg)
    };
    let a = run(NAIVE);
    assert!(a.drs_sleeps > 0, "diurnal churn never slept a node");
    for (vi, k) in EXACT_VARIANTS.iter().enumerate() {
        let b = run(*k);
        assert_eq!(a.scheduled, b.scheduled, "variant{vi}");
        assert_eq!(a.failed, b.failed, "variant{vi}");
        assert_eq!(a.drs_sleeps, b.drs_sleeps, "variant{vi}: sleep schedule diverged");
        assert_eq!(a.drs_wakes, b.drs_wakes, "variant{vi}: wake schedule diverged");
        assert_eq!(
            a.steady_eopc_w.to_bits(),
            b.steady_eopc_w.to_bits(),
            "variant{vi}: steady EOPC diverged"
        );
        assert_eq!(
            a.mean_asleep_nodes.to_bits(),
            b.mean_asleep_nodes.to_bits(),
            "variant{vi}: asleep-node series diverged"
        );
    }
}

/// The DSL wiring: `sample(100)|shards(2)` through `--policy` parsing
/// must behave exactly like the hand-set knobs (and like the naive
/// loop, since 100% sampling keeps the sweep exhaustive).
#[test]
fn dsl_knobs_match_hand_set_knobs() {
    let cluster = ClusterSpec::tiny(6, 4, 1);
    let trace = TraceSpec::default_trace();
    let base = run_inflation("pwrfgd:0.5", NAIVE, &cluster, &trace, 7, 0.7);
    let via_dsl = {
        let sched = SchedulerProfile::parse(
            "score(pwr=0.5,fgd=0.5)|bind(weighted:0.5)|sample(100)|shards(2)",
        )
        .unwrap()
        .build()
        .unwrap();
        let dc = cluster.build();
        let workload = trace.synthesize(7 ^ 0x57AB1E).workload();
        let mut sim = Simulation::with_spec(dc, sched, &trace, workload, 7);
        sim.record_frag = false;
        sim.run_inflation(0.7)
    };
    assert_bit_identical("dsl-knobs", &base, &via_dsl);
}

/// The lossy side of `sample(<pct>)`: on a fleet larger than the
/// 100-feasible-node floor the sweep truncates, yet every decision
/// lands on a real feasible node and the truncation is observable.
#[test]
fn sampled_sweep_truncates_but_places_validly() {
    use repro::tasks::{GpuDemand, Task, Workload};
    let mut dc = ClusterSpec::tiny(160, 4, 0).build();
    let mut sched = SchedulerProfile::parse("score(fgd)|sample(25)")
        .unwrap()
        .build()
        .unwrap();
    let w = Workload::default();
    for i in 0..32u64 {
        let t = Task::new(i, 1.0, 0.0, GpuDemand::Frac(0.5));
        let d = sched
            .place(&mut dc, &w, &t)
            .expect("sampled sweep failed feasible demand");
        assert!(d.node < 160, "placed on a nonexistent node");
    }
    assert_eq!(
        sched.metrics().counter("sched_sampled_sweeps"),
        32,
        "every decision should have taken the sampled sweep"
    );
}
