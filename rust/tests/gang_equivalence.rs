//! Gang-subsystem equivalence + atomicity suite (`docs/gang.md`).
//!
//! The gang machinery must be strictly additive:
//!
//! * **Gang-free traces are bit-identical to the pre-gang scheduler.**
//!   Singleton arrivals route through the unchanged `place`/`release`
//!   protocol, `gang-0` synthesizes the exact Default trace, and the
//!   `topo`/`zonespread` score plugins are flat surfaces on gang-free
//!   load (0 raw → constant 100 normalized), so composing them at any
//!   weight changes no decision — across policies × traces × seeds, in
//!   both simulation loops.
//! * **All-or-nothing.** A gang that fails mid-placement rolls its
//!   committed prefix back exactly: task counts, allocation caches,
//!   per-node free state and the fleet revision stamp return to their
//!   pre-call values, and subsequent decisions are indistinguishable
//!   from a scheduler that never saw the gang.
//! * **TP locality.** Placed gangs never split a tensor-parallel group
//!   across nodes (`gang_tp_violations` stays 0 on a `gang-50` run).
//! * **Fast-path safety.** The score cache, sharding, and
//!   `sample(100)` stay bit-identical on gang traces (the
//!   non-cacheable `topo` plugin is bypassed, not frozen).

use repro::cluster::node::{Placement, ResourceView};
use repro::cluster::ClusterSpec;
use repro::sched::gang::gang_task;
use repro::sched::{Scheduler, SchedulerProfile};
use repro::sim::events::{SteadyConfig, SteadySim};
use repro::sim::{RunResult, Simulation};
use repro::tasks::{GangSpec, GpuDemand, Task, Workload};
use repro::trace::TraceSpec;

fn sched(policy: &str) -> Scheduler {
    SchedulerProfile::parse(policy).unwrap().build().unwrap()
}

fn run_inflation(
    policy: &str,
    cluster: &ClusterSpec,
    trace: &TraceSpec,
    seed: u64,
    target: f64,
) -> RunResult {
    let dc = cluster.build();
    let workload = trace.synthesize(seed ^ 0x57AB1E).workload();
    let mut sim = Simulation::with_spec(dc, sched(policy), trace, workload, seed);
    sim.record_frag = false;
    sim.run_inflation(target)
}

fn assert_bit_identical(what: &str, a: &RunResult, b: &RunResult) {
    assert_eq!(a.submitted, b.submitted, "{what}: submitted diverged");
    assert_eq!(a.scheduled, b.scheduled, "{what}: scheduled diverged");
    assert_eq!(a.failed, b.failed, "{what}: failed diverged");
    assert_eq!(
        a.allocated_gpu_units.to_bits(),
        b.allocated_gpu_units.to_bits(),
        "{what}: allocated units diverged"
    );
    assert_eq!(
        a.final_eopc().to_bits(),
        b.final_eopc().to_bits(),
        "{what}: final EOPC diverged"
    );
    assert_eq!(a.final_grar().to_bits(), b.final_grar().to_bits(), "{what}: GRAR diverged");
    assert_eq!(a.gangs_placed, b.gangs_placed, "{what}: gangs_placed diverged");
    assert_eq!(a.gangs_failed, b.gangs_failed, "{what}: gangs_failed diverged");
    assert_eq!(a.gang_pp_span_sum, b.gang_pp_span_sum, "{what}: span sum diverged");
}

/// `topo` and `zonespread` composed at any weight are invisible on
/// gang-free, class-free traces: both plugins raw-score 0 everywhere,
/// which normalizes to a constant 100 on every node — the argmax, the
/// tie sets and therefore the tie-break RNG stream are untouched.
#[test]
fn topo_and_zonespread_are_inert_on_gang_free_traces() {
    let cluster = ClusterSpec::tiny(6, 4, 1);
    let traces = [
        TraceSpec::default_trace(),
        TraceSpec::sharing_gpu(1.0),
        TraceSpec::multi_gpu(0.2),
    ];
    let pairs = [
        (
            "score(pwr=0.1,fgd=0.9)|bind(weighted:0.1)",
            "score(pwr=0.1,fgd=0.9,topo=0.4,zonespread=0.2)|bind(weighted:0.1)",
        ),
        ("score(fgd)", "score(fgd,topo=1,zonespread=1)"),
    ];
    for (base_policy, with_policy) in pairs {
        for trace in &traces {
            for seed in [1u64, 42] {
                let what = format!("{with_policy}/{}/seed{seed}", trace.name);
                let base = run_inflation(base_policy, &cluster, trace, seed, 0.7);
                assert!(base.submitted > 0, "{what}: empty run");
                assert_eq!(base.gangs_placed + base.gangs_failed, 0, "{what}: gangs?");
                let with = run_inflation(with_policy, &cluster, trace, seed, 0.7);
                assert_bit_identical(&what, &base, &with);
            }
        }
    }
}

/// `gang-0` carries the gang profiles at weight zero: it samples the
/// byte-identical task stream Default does, and the run decides
/// bit-identically — the gang machinery never engages.
#[test]
fn gang_zero_trace_is_bit_identical_to_default() {
    let cluster = ClusterSpec::tiny(6, 4, 1);
    let default = TraceSpec::default_trace();
    let gang0 = TraceSpec::gang_trace(0.0);
    for seed in [1u64, 42] {
        let a = default.synthesize(seed);
        let b = gang0.synthesize(seed);
        assert_eq!(a.tasks, b.tasks, "seed {seed}: task streams diverged");
    }
    for policy in ["pwrfgd:0.1", "bestfit"] {
        for seed in [7u64, 42] {
            let what = format!("{policy}/seed{seed}");
            let base = run_inflation(policy, &cluster, &default, seed, 0.7);
            let with = run_inflation(policy, &cluster, &gang0, seed, 0.7);
            assert_bit_identical(&what, &base, &with);
        }
    }
}

/// The second loop: steady-state churn on gang-free load must agree bit
/// for bit too, both for the `gang-0` trace and for composed
/// `topo`/`zonespread` weights.
#[test]
fn gang_free_churn_is_bit_identical() {
    let cfg = SteadyConfig {
        mean_interarrival_s: 1.0,
        mean_duration_s: 250.0,
        horizon_s: 2_500.0,
        sample_every_s: 50.0,
        seed: 9,
    };
    let cluster = ClusterSpec::tiny(8, 4, 2);
    let run = |policy: &str, trace: &TraceSpec| {
        let mut sim = SteadySim::new(cluster.build(), sched(policy), trace, &cfg);
        sim.run(&cfg)
    };
    let base = run("pwrfgd:0.1", &TraceSpec::default_trace());
    assert!(base.arrivals > 1_000, "arrivals {}", base.arrivals);
    let variants = [
        run("pwrfgd:0.1", &TraceSpec::gang_trace(0.0)),
        run(
            "score(pwr=0.1,fgd=0.9,topo=0.4,zonespread=0.2)|bind(weighted:0.1)",
            &TraceSpec::default_trace(),
        ),
    ];
    for (vi, b) in variants.iter().enumerate() {
        assert_eq!(base.arrivals, b.arrivals, "variant{vi}");
        assert_eq!(base.scheduled, b.scheduled, "variant{vi}");
        assert_eq!(base.failed, b.failed, "variant{vi}");
        assert_eq!(base.departures, b.departures, "variant{vi}");
        assert_eq!(
            base.steady_eopc_w.to_bits(),
            b.steady_eopc_w.to_bits(),
            "variant{vi}: steady EOPC diverged"
        );
        assert_eq!(b.gangs_placed + b.gangs_failed, 0, "variant{vi}: gangs?");
    }
}

/// A gang that fails mid-placement is indistinguishable from one never
/// attempted: the committed prefix unwinds exactly (task count,
/// allocation caches, per-node free state, fleet revision), and a
/// control scheduler that never saw the gang makes the identical next
/// decision.
#[test]
fn failed_gang_rolls_back_exactly() {
    // Two 4-GPU/96-vCPU nodes; node 1 pre-loaded with a 20-vCPU
    // CPU-only filler. A 2-member gang of Whole(4) + 80 vCPUs per
    // member passes every PreFilter (aggregate CPU 160 ≤ 172, two
    // NVLink-contiguous 4-GPU groups free) but only node 0 can host a
    // member — member 1 must fail and unwind member 0.
    let spec = GangSpec::new(4, 2, 1).unwrap();
    let build_dc = || {
        let mut dc = ClusterSpec::tiny(2, 4, 0).build();
        let filler = Task::new(99, 20.0, 0.0, GpuDemand::Zero);
        dc.allocate(&filler, 1, &Placement::CpuOnly);
        dc
    };
    let mut dc = build_dc();
    let w = Workload::default();
    let mut s = sched("score(pwr=0.1,fgd=0.9)");

    let n_tasks_before = dc.n_tasks;
    let caches_before = dc.recompute_caches();
    let revision_before = dc.revision();
    let free_before: Vec<(f64, usize)> =
        dc.nodes.iter().map(|n| (n.cpu_free(), n.gpus_fully_free())).collect();

    let doomed = gang_task(1, 80.0, 1_024.0, spec);
    assert!(s.place_gang(&mut dc, &w, &doomed).is_none(), "doomed gang placed?");

    assert_eq!(dc.n_tasks, n_tasks_before, "partial gang left committed");
    assert_eq!(dc.recompute_caches(), caches_before, "allocation caches drifted");
    assert_eq!(dc.revision(), revision_before, "fleet revision drifted");
    let free_after: Vec<(f64, usize)> =
        dc.nodes.iter().map(|n| (n.cpu_free(), n.gpus_fully_free())).collect();
    assert_eq!(free_after, free_before, "per-node free state drifted");
    let m = s.metrics();
    assert_eq!(m.counter("gangs_failed"), 1);
    assert_eq!(m.counter("gangs_placed"), 0);
    assert_eq!(m.counter("gang_tp_violations"), 0);

    // Control: a scheduler + datacenter that never saw the gang must
    // make the identical next decision (state, caches and the
    // tie-break RNG stream all agree). CPU-only so both nodes stay
    // fully GPU-free for the fitting gang below — and so both nodes
    // are candidates, exercising the tie-break stream.
    let mut control_dc = build_dc();
    let mut control = sched("score(pwr=0.1,fgd=0.9)");
    let probe = Task::new(2, 4.0, 8_192.0, GpuDemand::Zero);
    let d_rolled = s.place(&mut dc, &w, &probe);
    let d_control = control.place(&mut control_dc, &w, &probe);
    assert_eq!(d_rolled, d_control, "post-rollback decision diverged from control");

    // And a gang that fits commits whole: both members, one TP group
    // of exactly `tp` whole GPUs each, on single nodes.
    let fits = gang_task(3, 10.0, 512.0, spec);
    let d = s.place_gang(&mut dc, &w, &fits).expect("feasible gang failed");
    assert_eq!(d.members.len(), 2);
    for member in &d.members {
        match &member.placement {
            Placement::Whole { gpus } => assert_eq!(gpus.len(), 4, "TP group split"),
            other => panic!("gang member bound to {other:?}"),
        }
    }
    assert_ne!(d.members[0].node, d.members[1].node, "4+4 GPUs cannot share a node");
    let m = s.metrics();
    assert_eq!(m.counter("gangs_placed"), 1);
    assert_eq!(m.counter("gang_tp_violations"), 0);
    assert_eq!(m.counter("gang_pp_span_sum"), 2);
    assert_eq!(dc.n_tasks, n_tasks_before + 3, "probe + both members resident");
}

/// Cluster-wide hopeless gangs die in PreFilter: no member is ever
/// attempted, nothing is committed.
#[test]
fn hopeless_gang_is_prefiltered_without_commits() {
    // One GPU busy per node: 6 whole GPUs free in aggregate, so the
    // `resources` PreFilter passes a 3×Whole(2) gang — but only
    // ⌊3/2⌋·2 = 2 NVLink-contiguous pairs exist, so the `gang`
    // PreFilter is the decisive cluster-wide veto.
    let mut dc = ClusterSpec::tiny(2, 4, 0).build();
    let filler = Task::new(99, 1.0, 0.0, GpuDemand::Whole(1));
    dc.allocate(&filler, 0, &Placement::Whole { gpus: vec![0] });
    dc.allocate(&filler, 1, &Placement::Whole { gpus: vec![0] });
    let n_before = dc.n_tasks;
    let w = Workload::default();
    let mut s = sched("score(fgd)");
    let gang = gang_task(1, 1.0, 0.0, GangSpec::new(2, 3, 1).unwrap());
    assert!(s.place_gang(&mut dc, &w, &gang).is_none());
    assert_eq!(dc.n_tasks, n_before, "prefiltered gang committed state");
    let m = s.metrics();
    assert_eq!(m.counter("gangs_failed"), 1);
    assert_eq!(m.counter("sched_prefilter_rejections"), 1);
}

/// A task without a gang through `place_gang` is exactly `place`: the
/// one-member fall-through.
#[test]
fn singleton_through_place_gang_matches_place() {
    let w = Workload::default();
    let t = Task::new(5, 4.0, 8_192.0, GpuDemand::Whole(2));
    let mut dc_a = ClusterSpec::tiny(4, 4, 0).build();
    let mut s_a = sched("pwrfgd:0.1");
    let direct = s_a.place(&mut dc_a, &w, &t).expect("place failed");
    let mut dc_b = ClusterSpec::tiny(4, 4, 0).build();
    let mut s_b = sched("pwrfgd:0.1");
    let via_gang = s_b.place_gang(&mut dc_b, &w, &t).expect("place_gang failed");
    assert_eq!(via_gang.members, vec![direct]);
    // The fall-through counts as an ordinary place, not a gang.
    assert_eq!(s_b.metrics().counter("gangs_placed"), 0);
}

/// End to end on a `gang-50` trace with `topo` composed in: gangs
/// place, no TP group ever crosses a node, and the mean PP span is
/// sane (≥ 1 node per gang).
#[test]
fn gang50_places_gangs_with_zero_cross_node_tp_groups() {
    let cluster = ClusterSpec::tiny(8, 4, 0).with_zones(2);
    let trace = TraceSpec::gang_trace(0.5);
    for policy in ["score(pwr=0.1,fgd=0.9)", "score(pwr=0.1,fgd=0.6,topo=0.3)"] {
        let out = run_inflation(policy, &cluster, &trace, 7, 0.8);
        assert!(out.gangs_placed > 0, "{policy}: no gang placed");
        assert_eq!(out.gang_tp_violations, 0, "{policy}: TP group crossed a node");
        assert!(
            out.gang_pp_span_sum >= out.gangs_placed,
            "{policy}: span sum {} < gangs {}",
            out.gang_pp_span_sum,
            out.gangs_placed
        );
    }
}

/// The scale-out fast path on gang traces: score cache and sharded
/// scoring at `sample(100)` stay bit-identical to the naive loop —
/// the non-cacheable `topo` plugin is rescored, never cached, and
/// member commits invalidate the touched nodes.
#[test]
fn fast_path_is_bit_identical_on_gang_traces() {
    let cluster = ClusterSpec::tiny(8, 4, 0).with_zones(2);
    let trace = TraceSpec::gang_trace(0.5);
    let run = |policy: &str, cache: bool, shards: usize| {
        let mut s = sched(policy);
        s.set_score_cache(cache);
        s.set_score_shards(shards);
        s.set_sample_pct(100);
        let dc = cluster.build();
        let workload = trace.synthesize(7 ^ 0x57AB1E).workload();
        let mut sim = Simulation::with_spec(dc, s, &trace, workload, 7);
        sim.record_frag = false;
        sim.run_inflation(0.8)
    };
    for policy in ["score(pwr=0.1,fgd=0.9)", "score(pwr=0.1,fgd=0.6,topo=0.3,zonespread=0.1)"] {
        let base = run(policy, false, 1);
        assert!(base.gangs_placed > 0, "{policy}: no gang placed");
        for (vi, (cache, shards)) in [(true, 1), (false, 4), (true, 4)].iter().enumerate() {
            let with = run(policy, *cache, *shards);
            assert_bit_identical(&format!("{policy}/variant{vi}"), &base, &with);
            assert_eq!(
                base.gang_tp_violations, with.gang_tp_violations,
                "{policy}/variant{vi}"
            );
        }
    }
}

/// Decision tracing on gangs: every committed gang emits exactly one
/// JSONL event with a per-member bind record, the event count equals
/// `gangs_placed` (failed/rolled-back gangs leave no event), and each
/// event round-trips the schema in `docs/observability.md` — tracer
/// stamps, `now`, the parent task, and `n_members` consistent member
/// rows carrying node + placement.
#[test]
fn traced_gang50_run_roundtrips_gang_events() {
    use repro::obs::{DecisionTracer, TraceSink};
    use repro::util::json::{self, Json};

    let cluster = ClusterSpec::tiny(8, 4, 0).with_zones(2);
    let trace = TraceSpec::gang_trace(0.5);
    let profile = SchedulerProfile::parse("score(pwr=0.1,fgd=0.9)").unwrap();
    let mut s = profile.build().unwrap();
    let sink = TraceSink::memory();
    s.set_tracer(DecisionTracer::new(sink.clone(), &profile.label, 7));
    let dc = cluster.build();
    let workload = trace.synthesize(7 ^ 0x57AB1E).workload();
    let mut sim = Simulation::with_spec(dc, s, &trace, workload, 7);
    sim.record_frag = false;
    let out = sim.run_inflation(0.8);
    assert!(out.gangs_placed > 0, "no gang placed");

    let text = sink.contents();
    let mut gang_events = 0u64;
    for line in text.lines() {
        let ev = json::parse(line).expect("traced line parses as JSON");
        if ev.get("event").and_then(Json::as_str) != Some("gang") {
            continue;
        }
        gang_events += 1;
        // Tracer stamps shared with every traced event.
        assert_eq!(ev.get("policy").and_then(Json::as_str), Some(profile.label.as_str()));
        assert_eq!(ev.get("seed").and_then(Json::as_u64), Some(7));
        assert!(ev.get("seq").and_then(Json::as_u64).is_some(), "missing seq");
        // Gang schema: clock, parent task, per-member bind records.
        assert!(ev.get("now").and_then(Json::as_u64).is_some(), "missing now");
        let task_id =
            ev.get("task").and_then(|t| t.get("id")).and_then(Json::as_u64);
        assert!(task_id.is_some(), "missing task.id");
        let n = ev.get("n_members").and_then(Json::as_u64).expect("n_members");
        let members = ev.get("members").and_then(Json::as_arr).expect("members array");
        assert_eq!(members.len() as u64, n, "n_members != members.len()");
        assert!(n >= 1, "gang event with no members");
        for (i, m) in members.iter().enumerate() {
            assert_eq!(
                m.get("member").and_then(Json::as_u64),
                Some(i as u64),
                "member rows out of order"
            );
            assert!(m.get("node").and_then(Json::as_u64).is_some(), "missing node");
            // TP groups bind whole GPUs, never shared slices.
            let placement = m.get("placement").and_then(Json::as_str).expect("placement");
            assert!(placement.contains("Whole"), "gang member bound to {placement}");
        }
        assert!(matches!(ev.get("hooks"), Some(Json::Obj(_))), "missing hooks");
    }
    assert_eq!(gang_events, out.gangs_placed, "gang events != gangs_placed");
}
