//! Property-based tests (hand-rolled generators over the in-repo seeded
//! RNG — the vendored crate set has no `proptest`): random operation
//! sequences against the coordinator and scheduler, checking the
//! invariants that must hold for *every* policy on *every* workload:
//!
//! * resource conservation — incremental caches equal recomputation;
//! * legality — every bound placement satisfies Cond. 1–3 at bind time;
//! * no oversubscription — GPU/CPU/MEM allocations never exceed capacity;
//! * power bounds — idle ≤ EOPC ≤ theoretical max, and EOPC returns to
//!   idle after all tasks are released;
//! * GRAR ∈ [0, 1] and failures are counted exactly.

use repro::cluster::node::Placement;
use repro::cluster::ClusterSpec;
use repro::coordinator::CoordinatorState;
use repro::power;
use repro::sched::PolicyKind;
use repro::tasks::{GpuDemand, Task};
use repro::trace::TraceSpec;
use repro::util::rng::Rng;

const POLICIES: [PolicyKind; 7] = [
    PolicyKind::Fgd,
    PolicyKind::Pwr,
    PolicyKind::PwrFgd { alpha: 0.1 },
    PolicyKind::BestFit,
    PolicyKind::DotProd,
    PolicyKind::GpuPacking,
    PolicyKind::GpuClustering,
];

fn theoretical_max_power(dc: &repro::cluster::Datacenter) -> f64 {
    dc.nodes
        .iter()
        .map(|n| {
            let sockets = (n.vcpus / n.cpu_model.vcpus_per_socket()).ceil();
            let cpu = n.cpu_model.p_max() * sockets;
            let gpu = n
                .gpu_model
                .map(|m| m.p_max() * n.gpu_alloc.len() as f64)
                .unwrap_or(0.0);
            cpu + gpu
        })
        .sum()
}

/// Random submit/release interleavings against every policy.
#[test]
fn coordinator_invariants_under_random_ops() {
    for (pi, &policy) in POLICIES.iter().enumerate() {
        let dc = ClusterSpec::paper_scaled(0.03).build();
        let idle = power::p_datacenter(&dc);
        let pmax = theoretical_max_power(&dc);
        let workload = TraceSpec::default_trace().synthesize(pi as u64).workload();
        let mut st = CoordinatorState::new(dc, policy, workload);
        let mut rng = Rng::new(1000 + pi as u64);
        let mut sampler = TraceSpec::default_trace().sampler(2000 + pi as u64);
        let mut live: Vec<u64> = Vec::new();

        for step in 0..400 {
            if !live.is_empty() && rng.bernoulli(0.35) {
                // Release a random live task.
                let idx = rng.below(live.len());
                let id = live.swap_remove(idx);
                assert!(st.release(id), "release of live task {id} failed");
            } else {
                let task = sampler.next_task();
                let id = task.id;
                if st.submit(task).is_some() {
                    live.push(id);
                }
            }
            // --- Invariants, every step. ---
            let (gpu, cpu) = st.dc.recompute_caches();
            assert!(
                (gpu - st.dc.gpu_allocated_units()).abs() < 1e-6,
                "[{policy:?} step {step}] gpu cache drift: {gpu} vs {}",
                st.dc.gpu_allocated_units()
            );
            assert!((cpu - st.dc.cpu_allocated_units()).abs() < 1e-6);
            for node in &st.dc.nodes {
                assert!(node.cpu_alloc <= node.vcpus + 1e-6, "cpu oversubscribed");
                assert!(node.mem_alloc <= node.mem + 1e-6, "mem oversubscribed");
                for (g, &a) in node.gpu_alloc.iter().enumerate() {
                    assert!((0.0..=1.0 + 1e-9).contains(&a), "gpu {g} alloc {a}");
                }
            }
            let p = power::p_datacenter(&st.dc);
            assert!(p >= idle - 1e-6 && p <= pmax + 1e-6, "power {p} outside [{idle},{pmax}]");
            assert_eq!(st.dc.n_tasks as usize, live.len());
        }
        // Drain: release everything; power must return to idle exactly.
        for id in live.drain(..) {
            assert!(st.release(id));
        }
        let p = power::p_datacenter(&st.dc);
        assert!((p - idle).abs() < 1e-6, "[{policy:?}] {p} != idle {idle}");
        assert_eq!(st.dc.n_tasks, 0);
    }
}

/// Every decision any policy takes must be legal at bind time, for all
/// task shapes including constrained ones.
#[test]
fn all_policies_bind_legal_placements() {
    for (pi, &policy) in POLICIES.iter().enumerate() {
        let mut dc = ClusterSpec::paper_scaled(0.03).build();
        let workload = TraceSpec::constrained_gpu(0.25).synthesize(pi as u64).workload();
        let mut sched = repro::sched::Scheduler::from_policy(policy);
        let mut sampler = TraceSpec::constrained_gpu(0.25).sampler(7 + pi as u64);
        for _ in 0..500 {
            let task = sampler.next_task();
            if let Some(d) = sched.schedule(&dc, &workload, &task) {
                let node = &dc.nodes[d.node];
                assert!(
                    node.placement_fits(&task, &d.placement),
                    "{policy:?} bound illegal placement {:?} for {task:?}",
                    d.placement
                );
                // Constraint respected.
                if let Some(required) = task.gpu_model {
                    assert_eq!(node.gpu_model, Some(required));
                }
                // Whole placements use fully-free GPUs only.
                if let Placement::Whole { gpus } = &d.placement {
                    for &g in gpus {
                        assert_eq!(node.gpu_alloc[g], 0.0);
                    }
                }
                dc.allocate(&task, d.node, &d.placement);
                sched.notify_node_changed(d.node);
            }
        }
    }
}

/// Fractional tasks sharing one GPU never exceed it; the `u_n` scalar
/// stays consistent with allocations.
#[test]
fn gpu_sharing_never_oversubscribes() {
    let mut rng = Rng::new(99);
    let fracs = [0.1, 0.2, 0.25, 0.3, 0.5, 0.6, 0.75];
    for trial in 0..50 {
        let mut dc = ClusterSpec::tiny(2, 4, 0).build();
        let workload = TraceSpec::default_trace().synthesize(trial).workload();
        let mut sched =
            repro::sched::Scheduler::from_policy(PolicyKind::PwrFgd { alpha: 0.1 });
        for i in 0..200 {
            let d = *rng.choice(&fracs);
            let task = Task::new(i, 1.0, 256.0, GpuDemand::Frac(d));
            if let Some(dec) = sched.schedule(&dc, &workload, &task) {
                dc.allocate(&task, dec.node, &dec.placement);
                sched.notify_node_changed(dec.node);
            }
            for node in &dc.nodes {
                for &a in &node.gpu_alloc {
                    assert!(a <= 1.0 + 1e-9, "trial {trial}: GPU oversubscribed to {a}");
                }
                // u_n must equal the definition recomputed from scratch.
                use repro::cluster::node::ResourceView;
                let by_hand: f64 = node.gpus_fully_free() as f64 + node.largest_partial_free();
                assert!((node.u_n() - by_hand).abs() < 1e-12);
            }
        }
    }
}

/// The savings computation is antisymmetric and zero against itself.
#[test]
fn savings_metric_properties() {
    use repro::metrics::savings_pct;
    let mut rng = Rng::new(5);
    for _ in 0..100 {
        let a: Vec<f64> = (0..20).map(|_| rng.range_f64(1e5, 1e6)).collect();
        let b: Vec<f64> = (0..20).map(|_| rng.range_f64(1e5, 1e6)).collect();
        let s_ab = savings_pct(&a, &b);
        let s_aa = savings_pct(&a, &a);
        assert!(s_aa.iter().all(|&s| s.abs() < 1e-9));
        for (i, &s) in s_ab.iter().enumerate() {
            // savings of b vs a: s = 100(a-b)/a  ⇒  b = a(1-s/100)
            let back = a[i] * (1.0 - s / 100.0);
            assert!((back - b[i]).abs() < 1e-6);
        }
    }
}

/// Trace derivations preserve their invariants for arbitrary knob
/// settings (not just the paper's four points).
#[test]
fn trace_derivations_hold_for_arbitrary_knobs() {
    let mut rng = Rng::new(31);
    for _ in 0..20 {
        let s = rng.range_f64(0.05, 1.0);
        let spec = TraceSpec::sharing_gpu(s);
        let share = spec.gpu_share_pct();
        assert!((share[1] / 100.0 - s).abs() < 1e-9, "share target {s}");

        let pct = rng.range_f64(0.0, 0.9);
        let spec = TraceSpec::constrained_gpu(pct);
        let trace = spec.synthesize(rng.next_u64());
        let gpu_tasks = trace.tasks.iter().filter(|t| t.gpu.is_gpu()).count();
        let constrained =
            trace.tasks.iter().filter(|t| t.gpu_model.is_some()).count();
        let frac = constrained as f64 / gpu_tasks.max(1) as f64;
        assert!((frac - pct).abs() < 0.05, "constrained {frac} vs {pct}");

        let m = rng.range_f64(0.05, 0.6);
        let spec = TraceSpec::multi_gpu(m);
        // population of CPU-only and sharing buckets unchanged vs default
        let base = TraceSpec::default_trace();
        let (p_new, p_base) = (spec.population_pct(), base.population_pct());
        assert!(p_new[0] < p_base[0] + 0.01); // multi tasks grew => others' share shrank
        assert!(p_new[3] + p_new[4] + p_new[5] > p_base[3] + p_base[4] + p_base[5]);
    }
}

/// Randomized pending-queue invariants (`rust/src/sched/fairness.rs`):
/// under any interleaving of enqueue (a failed placement), drain (a
/// successful retry after a release) and clock ticks, the queue stays
/// ordered priority-descending / FIFO within a priority tier, drains
/// always serve the head, the queue tracks a plain reference model
/// exactly, and `oldest_pending_age` is monotone between retries while
/// the oldest entry keeps waiting.
#[test]
fn pending_queue_invariants_under_random_interleavings() {
    use repro::sched::{FairnessConfig, FairnessCore};
    for round in 0..10u64 {
        let mut rng = Rng::new(4_000 + round);
        let mut core = FairnessCore::new(FairnessConfig { starve_threshold: 25.0 });
        let mut now = 0.0;
        let mut next_id = 0u64;
        let mut last_oldest = 0.0;
        // Reference model: (priority, id) with the same insertion rule.
        let mut expected: Vec<(u8, u64)> = Vec::new();
        for _ in 0..600 {
            match rng.next_u64() % 4 {
                0 | 1 => {
                    // Failed placement: enqueue with a random priority.
                    let prio = (rng.next_u64() % 3) as u8;
                    let task = Task::new(next_id, 1.0, 64.0, GpuDemand::Frac(0.25))
                        .with_priority(prio);
                    core.enqueue(task, false);
                    let at = expected
                        .iter()
                        .position(|(p, _)| *p < prio)
                        .unwrap_or(expected.len());
                    expected.insert(at, (prio, next_id));
                    next_id += 1;
                }
                2 => {
                    // Successful retry: the drained entry must be the head.
                    if let Some(head) = core.head() {
                        let popped = core.pop_placed().unwrap();
                        assert_eq!(popped.task.id, head.id, "round {round}: pop != head");
                        let (prio, id) = expected.remove(0);
                        assert_eq!(
                            (popped.task.priority, popped.task.id),
                            (prio, id),
                            "round {round}: drain order diverged from the model"
                        );
                        // The pop may have removed the oldest entry —
                        // reset the monotonicity baseline.
                        last_oldest = 0.0;
                    }
                }
                _ => {
                    // Tick: the clock only moves forward, ages only grow.
                    now += rng.range_f64(0.1, 5.0);
                    core.set_now(now);
                }
            }
            // FIFO within priority: (priority desc, seq asc) everywhere.
            let entries = core.pending_entries();
            for w in entries.windows(2) {
                assert!(
                    w[0].task.priority > w[1].task.priority
                        || (w[0].task.priority == w[1].task.priority
                            && w[0].seq < w[1].seq),
                    "round {round}: queue not (priority desc, FIFO) ordered"
                );
            }
            let got: Vec<(u8, u64)> =
                entries.iter().map(|e| (e.task.priority, e.task.id)).collect();
            assert_eq!(got, expected, "round {round}: queue diverged from the model");
            // oldest_pending_age never shrinks while the oldest waits.
            let oldest = core.oldest_pending_age();
            assert!(
                oldest + 1e-9 >= last_oldest,
                "round {round}: oldest age shrank without a drain \
                 ({oldest} < {last_oldest})"
            );
            last_oldest = if core.pending_depth() > 0 { oldest } else { 0.0 };
            // The starvation ledger fires at most once per queue stint.
            assert!(core.starvation_events() <= core.enqueues() + core.requeues());
        }
    }
}
