//! Fairness equivalence + conservation suite (`docs/fairness.md`).
//!
//! The pending-queue fairness subsystem (`rust/src/sched/fairness.rs`)
//! must be invisible when disabled: a scheduler carrying `mod(starve)`
//! and `hook(preempt)` sections that were never bound to a fairness
//! core — and a simulation that never calls `enable_fairness` — has to
//! produce **bit-identical** fixed-seed runs against the plain
//! profile, across policies × trace families × seeds, in both
//! simulation loops (inflation and steady-state churn).
//!
//! The suite also pins the active side under `priority-<pct>` churn:
//! every arrival is exactly one of allocated / pending / departed
//! (nothing vanishes once the queue is on), the enqueue/drain ledger
//! is consistent with the starvation counters, preemption never evicts
//! an equal-or-higher-priority resident, and victims' resources are
//! restored exactly.

use repro::cluster::ClusterSpec;
use repro::sched::{FairnessConfig, FairnessState, SchedulerProfile};
use repro::sim::events::{SteadyConfig, SteadySim};
use repro::sim::{RunResult, Simulation};
use repro::trace::TraceSpec;

/// Inflation run; `fairness_off_extras` appends inert (unbound)
/// fairness sections to the profile without enabling the queue.
fn run_inflation(
    policy: &str,
    cluster: &ClusterSpec,
    trace: &TraceSpec,
    seed: u64,
    target: f64,
) -> RunResult {
    let sched = SchedulerProfile::parse(policy).unwrap().build().unwrap();
    let dc = cluster.build();
    let workload = trace.synthesize(seed ^ 0x57AB1E).workload();
    let mut sim = Simulation::with_spec(dc, sched, trace, workload, seed);
    sim.record_frag = false;
    sim.run_inflation(target)
}

fn assert_bit_identical(what: &str, a: &RunResult, b: &RunResult) {
    assert_eq!(a.submitted, b.submitted, "{what}: submitted diverged");
    assert_eq!(a.scheduled, b.scheduled, "{what}: scheduled diverged");
    assert_eq!(a.failed, b.failed, "{what}: failed diverged");
    assert_eq!(
        a.allocated_gpu_units.to_bits(),
        b.allocated_gpu_units.to_bits(),
        "{what}: allocated units diverged"
    );
    assert_eq!(
        a.final_eopc().to_bits(),
        b.final_eopc().to_bits(),
        "{what}: final EOPC diverged ({} vs {})",
        a.final_eopc(),
        b.final_eopc()
    );
    assert_eq!(
        a.final_grar().to_bits(),
        b.final_grar().to_bits(),
        "{what}: final GRAR diverged"
    );
}

/// Unbound fairness plugins are inert: bit-identical inflation runs
/// with and without `mod(starve)`/`hook(preempt)` in the profile,
/// across weight mixes × traces × seeds. The queue itself is never
/// enabled, so the run also pins the fairness-off (seed) behavior of
/// the refactored step loop.
#[test]
fn unbound_fairness_plugins_are_bit_identical_in_inflation() {
    let cluster = ClusterSpec::tiny(6, 4, 1);
    let traces = [
        TraceSpec::default_trace(),
        TraceSpec::sharing_gpu(1.0),
        TraceSpec::multi_gpu(0.2),
        TraceSpec::priority_trace(0.5),
    ];
    let pairs = [
        (
            "score(pwr=0.1,fgd=0.9)|bind(weighted:0.1)",
            "score(pwr=0.1,fgd=0.9)|bind(weighted:0.1)|mod(starve:100:0.5)|hook(preempt:4)",
        ),
        (
            "score(pwr=0.5,fgd=0.3,dotprod=0.2)|bind(weighted:0.5)",
            "score(pwr=0.5,fgd=0.3,dotprod=0.2)|bind(weighted:0.5)|mod(starve:1:1.0)|hook(preempt:8)",
        ),
        ("bestfit", "score(bestfit)|hook(preempt:2)"),
    ];
    for (base_policy, with_policy) in pairs {
        for trace in &traces {
            for seed in [1u64, 42] {
                let what = format!("{base_policy}/{}/seed{seed}", trace.name);
                let base = run_inflation(base_policy, &cluster, trace, seed, 0.7);
                let with = run_inflation(with_policy, &cluster, trace, seed, 0.7);
                assert!(base.submitted > 0, "{what}: empty run");
                assert_bit_identical(&what, &base, &with);
                assert_eq!(with.pending_depth, 0, "{what}: queue grew while disabled");
                assert_eq!(with.pending_enqueues, 0, "{what}: enqueued while disabled");
                assert_eq!(with.preemptions, 0, "{what}: preempted while unbound");
                assert_eq!(with.starvation_events, 0, "{what}: starved while disabled");
            }
        }
    }
}

/// The same pin under churn: the steady-state loop (arrivals +
/// departures through `Scheduler::place`/`release`) with unbound
/// fairness plugins and no `enable_fairness` call must agree bit for
/// bit with the plain profile.
#[test]
fn fairness_off_is_bit_identical_under_churn() {
    let cluster = ClusterSpec::tiny(8, 4, 2);
    let run = |policy: &str, trace: &TraceSpec, seed: u64| {
        let cfg = SteadyConfig {
            mean_interarrival_s: 1.0,
            mean_duration_s: 250.0,
            horizon_s: 2_500.0,
            sample_every_s: 50.0,
            seed,
        };
        let sched = SchedulerProfile::parse(policy).unwrap().build().unwrap();
        let mut sim = SteadySim::new(cluster.build(), sched, trace, &cfg);
        sim.run(&cfg)
    };
    for trace in [TraceSpec::default_trace(), TraceSpec::priority_trace(0.5)] {
        for seed in [9u64, 23] {
            let what = format!("{}/seed{seed}", trace.name);
            let a = run("score(pwr=0.1,fgd=0.9)|bind(weighted:0.1)", &trace, seed);
            let b = run(
                "score(pwr=0.1,fgd=0.9)|bind(weighted:0.1)|mod(starve:50:0.5)|hook(preempt:4)",
                &trace,
                seed,
            );
            assert!(a.arrivals > 1_000, "{what}: arrivals {}", a.arrivals);
            assert_eq!(a.arrivals, b.arrivals, "{what}: arrivals diverged");
            assert_eq!(a.scheduled, b.scheduled, "{what}: scheduled diverged");
            assert_eq!(a.failed, b.failed, "{what}: failed diverged");
            assert_eq!(a.departures, b.departures, "{what}: departures diverged");
            assert_eq!(
                a.steady_eopc_w.to_bits(),
                b.steady_eopc_w.to_bits(),
                "{what}: steady EOPC diverged"
            );
            assert_eq!(
                a.allocated_gpu_units.to_bits(),
                b.allocated_gpu_units.to_bits(),
                "{what}: allocated units diverged"
            );
            assert_eq!(b.pending_enqueues, 0, "{what}: enqueued while disabled");
            assert_eq!(b.preemptions, 0, "{what}: preempted while disabled");
        }
    }
}

/// Conservation under `priority-50` churn with the full subsystem on
/// (queue + `mod(starve)` + `hook(preempt)`), heavily overloaded so the
/// queue, the starvation ledger and the preemption path all engage:
/// * nothing vanishes — every arrival is allocated, departed or
///   pending (`failed` stays 0 on a gang-free trace),
/// * the enqueue/drain ledger balances (`enqueues + requeues =
///   drains + depth`),
/// * the starvation ledger is consistent (at most one event per queue
///   stint) and actually fired under overload.
#[test]
fn conservation_under_priority_churn() {
    let cfg = SteadyConfig {
        mean_interarrival_s: 1.0,
        mean_duration_s: 400.0,
        horizon_s: 4_000.0,
        sample_every_s: 100.0,
        seed: 7,
    };
    let trace = TraceSpec::priority_trace(0.5);
    let sched = SchedulerProfile::parse(
        "score(pwr=0.1,fgd=0.9)|bind(weighted:0.1)|mod(starve:50:0.5)|hook(preempt:8)",
    )
    .unwrap()
    .build()
    .unwrap();
    let mut sim = SteadySim::new(ClusterSpec::tiny(4, 4, 1).build(), sched, &trace, &cfg);
    sim.enable_fairness(FairnessConfig { starve_threshold: 50.0 });
    let r = sim.run(&cfg);
    assert!(r.arrivals > 2_000, "arrivals {}", r.arrivals);
    assert_eq!(r.failed, 0, "gang-free arrivals must never be dropped");
    // Every arrival is exactly one of: still allocated, departed,
    // or waiting in the queue. (Gang-free trace: one task = one
    // datacenter allocation.)
    assert_eq!(
        r.arrivals,
        sim.dc().n_tasks as u64 + r.departures + r.pending_depth,
        "arrivals leaked (running {} departed {} pending {})",
        sim.dc().n_tasks,
        r.departures,
        r.pending_depth
    );
    // Enqueue/drain ledger: everything that entered the queue either
    // drained into a placement or is still waiting.
    let (enq, req, drains, starved) = sim
        .fairness_shared()
        .map(|s| {
            let core = s.lock().unwrap();
            (core.enqueues(), core.requeues(), core.drains(), core.starvation_events())
        })
        .expect("fairness enabled");
    assert_eq!(
        enq + req,
        drains + r.pending_depth,
        "pending ledger out of balance"
    );
    assert_eq!(r.pending_enqueues, enq + req, "result snapshot diverged from core");
    assert_eq!(r.pending_drains, drains, "result snapshot diverged from core");
    assert!(enq > 0, "overloaded run never used the queue");
    assert!(starved <= enq + req, "more starvation events than queue stints");
    assert!(
        r.starvation_events > 0,
        "50s threshold never fired under sustained overload"
    );
    // Waits are real observations, not sentinel values.
    assert!(r.p99_wait >= 0.0 && r.p99_wait.is_finite());
    assert!(r.oldest_pending_age >= 0.0 && r.oldest_pending_age.is_finite());
}

/// Preemption end to end through the scheduler's postFail phase:
/// a high-priority arrival on a full node evicts only
/// strictly-lower-priority residents, victims re-enter the pending
/// queue as requeued entries, and the datacenter accounting after the
/// dust settles matches the surviving task set exactly.
#[test]
fn preemption_never_evicts_equal_or_higher_priority_and_restores_exactly() {
    use repro::cluster::Placement;
    use repro::tasks::{GpuDemand, Task, Workload};
    let mut dc = ClusterSpec::tiny(1, 4, 0).build();
    let w = Workload::default();
    let mut sched = SchedulerProfile::parse(
        "score(pwr=0.1,fgd=0.9)|bind(weighted:0.1)|hook(preempt:2)",
    )
    .unwrap()
    .build()
    .unwrap();
    let fs = FairnessState::new(FairnessConfig::default());
    sched.bind_fairness(fs.shared());
    // Fill the single node: priorities [0, 0, 1, 2], one whole GPU each.
    let mk = |id: u64, prio: u8| {
        Task::new(id, 2.0, 512.0, GpuDemand::Whole(1)).with_priority(prio)
    };
    for (id, prio) in [(0u64, 0u8), (1, 0), (2, 1), (3, 2)] {
        let task = mk(id, prio);
        let d = sched.place(&mut dc, &w, &task).expect("fill placement");
        fs.with_core(|c| c.note_resident(&task, d.node, &d.placement));
    }
    assert_eq!(dc.gpu_free_units(), 0.0);
    // High-priority two-GPU arrival: must evict exactly the two
    // cheapest best-effort tenants (never the priority-1/2 residents —
    // budget 2 cannot free two GPUs from the lone priority-1 victim
    // plus an equal-priority one, and equal priority is off-limits).
    let big = Task::new(10, 2.0, 512.0, GpuDemand::Whole(2)).with_priority(2);
    let d = sched.place(&mut dc, &w, &big).expect("preemption must free capacity");
    fs.with_core(|c| c.note_resident(&big, d.node, &d.placement));
    let victims = fs.with_core(|c| c.requeue_evicted());
    assert_eq!(victims.len(), 2, "expected exactly two evictions");
    assert!(
        victims.iter().all(|id| *id <= 1),
        "evicted a priority>=2 resident: {victims:?}"
    );
    let (depth, all_requeued, requeues) = fs.with_core(|c| {
        (
            c.pending_depth(),
            c.pending_entries().iter().all(|e| e.requeued && e.task.priority == 0),
            c.requeues(),
        )
    });
    assert_eq!(depth, 2, "victims must land in the pending queue");
    assert!(all_requeued, "victims must be marked as requeued best-effort entries");
    assert_eq!(requeues, 2);
    // Surviving set: tasks 2, 3 (one GPU each) + the new two-GPU task.
    // All sizes are exactly-representable integers, so the accounting
    // must match to the bit.
    assert_eq!(dc.n_tasks, 3);
    let node = &dc.nodes[0];
    assert_eq!(node.cpu_alloc, 6.0, "cpu not restored exactly");
    assert_eq!(node.mem_alloc, 1536.0, "mem not restored exactly");
    assert_eq!(node.gpu_alloc.iter().filter(|a| **a == 1.0).count(), 4);
    assert_eq!(node.gpu_alloc.iter().filter(|a| **a == 0.0).count(), 0);
    // A best-effort arrival must never trigger preemption, and with the
    // node full it simply fails.
    let be = Task::new(11, 1.0, 128.0, GpuDemand::Whole(1));
    assert!(sched.place(&mut dc, &w, &be).is_none());
    assert_eq!(fs.with_core(|c| c.preemptions()), 2, "best-effort arrival preempted");
    // Draining the queue after departures places the victims again.
    match d.placement {
        Placement::Whole { ref gpus } => assert_eq!(gpus.len(), 2),
        ref p => panic!("expected whole-GPU placement, got {p:?}"),
    }
    sched.release(&mut dc, &big, d.node, &d.placement);
    let head = fs.with_core(|c| c.head()).expect("queue has victims");
    let rd = sched.place(&mut dc, &w, &head).expect("freed capacity hosts a victim");
    let entry = fs.with_core(|c| c.pop_placed()).unwrap();
    assert!(entry.requeued, "drained entry must keep its requeued mark");
    assert_eq!(entry.task.id, head.id);
    fs.with_core(|c| c.note_resident(&entry.task, rd.node, &rd.placement));
    assert_eq!(fs.with_core(|c| c.pending_depth()), 1);
}

/// The inflation loop with the queue on: failed placements park in the
/// queue instead of counting as failures, and the arrival ledger
/// balances at the end of the run.
#[test]
fn inflation_queue_conserves_arrivals() {
    let cluster = ClusterSpec::tiny(2, 4, 0);
    let trace = TraceSpec::priority_trace(0.5);
    let sched = SchedulerProfile::parse("score(pwr=0.1,fgd=0.9)|bind(weighted:0.1)")
        .unwrap()
        .build()
        .unwrap();
    let workload = trace.synthesize(5 ^ 0x57AB1E).workload();
    let mut sim = Simulation::with_spec(cluster.build(), sched, &trace, workload, 5);
    sim.record_frag = false;
    sim.enable_fairness(FairnessConfig { starve_threshold: 100.0 });
    let r = sim.run_inflation(2.0);
    assert!(r.submitted > 0);
    assert_eq!(r.failed, 0, "queued arrivals must not count as failed");
    assert_eq!(
        r.submitted,
        r.scheduled + r.pending_depth,
        "inflation arrivals leaked (pending {})",
        r.pending_depth
    );
    assert!(r.pending_depth > 0, "2× capacity inflation never queued anything");
    assert!(r.pending_enqueues >= r.pending_depth);
}
