//! Legacy-profile equivalence regression suite.
//!
//! The `SchedulerProfile` redesign must be invisible to every
//! pre-existing `--policy` string: each legacy [`PolicyKind`] lowers to
//! a profile whose scheduler makes **bit-identical** decisions to the
//! pre-redesign hard-wired assembly. The reference schedulers below
//! replicate that assembly verbatim — the same plugin structs, weights,
//! binders and seeds the old `policies::build()` match wired — through
//! the raw [`Scheduler::new`] constructor; fixed-seed inflation runs
//! must then agree on submitted/scheduled/failed counts and on final
//! EOPC/GRAR to the last bit.
//!
//! (The build container has no Rust toolchain, so the old code can't be
//! executed side by side; replicating its wiring through the raw
//! constructor pins the *lowering*, while `sim::tests::same_seed_reproduces`
//! and the end-to-end suite pin the pipeline semantics.)

use repro::cluster::ClusterSpec;
use repro::sched::bind::{
    BestFitBinder, BindPlugin, FirstBinder, PackOccupiedBinder, RandomBinder, WeightedBinder,
};
use repro::sched::policies::{
    BestFitPlugin, DotProdPlugin, FgdPlugin, FirstFitPlugin, GpuClusteringPlugin,
    GpuPackingPlugin, MigRepartitioner, MigSliceFitPlugin, PwrPlugin, RandomPlugin,
    RepartitionConfig,
};
use repro::sched::{LoadAlphaModulator, PolicyKind, Scheduler, SchedulerProfile, ScorePlugin};
use repro::sim::{run_repetitions, RepeatConfig, RunResult, Simulation};
use repro::trace::TraceSpec;

/// The pre-redesign `policies::build()` wiring, replicated through the
/// raw constructor (plugin order, weights, binder kind and the RNG
/// seeds 0x5EED / 0xB14D are all load-bearing for bit-identity).
fn reference_scheduler(kind: PolicyKind) -> Scheduler {
    let label = kind.label();
    let (plugins, binder): (Vec<(Box<dyn ScorePlugin>, f64)>, Box<dyn BindPlugin>) = match kind {
        PolicyKind::Fgd | PolicyKind::MigFgd => (
            vec![(Box::new(FgdPlugin::new()) as Box<dyn ScorePlugin>, 1.0)],
            Box::new(WeightedBinder { alpha: 0.0 }),
        ),
        PolicyKind::Pwr | PolicyKind::MigPwr => (
            vec![(Box::new(PwrPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Box::new(WeightedBinder { alpha: 1.0 }),
        ),
        PolicyKind::PwrFgd { alpha } | PolicyKind::MigPwrFgd { alpha } => (
            vec![
                (Box::new(PwrPlugin) as Box<dyn ScorePlugin>, alpha),
                (Box::new(FgdPlugin::new()) as Box<dyn ScorePlugin>, 1.0 - alpha),
            ],
            Box::new(WeightedBinder { alpha }),
        ),
        PolicyKind::PwrFgdDynamic { alpha_empty, .. } => (
            vec![
                (Box::new(PwrPlugin) as Box<dyn ScorePlugin>, alpha_empty),
                (Box::new(FgdPlugin::new()) as Box<dyn ScorePlugin>, 1.0 - alpha_empty),
            ],
            Box::new(WeightedBinder { alpha: alpha_empty }),
        ),
        PolicyKind::BestFit | PolicyKind::MigBestFit => (
            vec![(Box::new(BestFitPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Box::new(BestFitBinder),
        ),
        PolicyKind::MigSliceFit => (
            vec![(Box::new(MigSliceFitPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Box::new(BestFitBinder),
        ),
        PolicyKind::DotProd => (
            vec![(Box::new(DotProdPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Box::new(BestFitBinder),
        ),
        PolicyKind::GpuPacking => (
            vec![(Box::new(GpuPackingPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Box::new(PackOccupiedBinder),
        ),
        PolicyKind::GpuClustering => (
            vec![(Box::new(GpuClusteringPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Box::new(BestFitBinder),
        ),
        PolicyKind::FirstFit => (
            vec![(Box::new(FirstFitPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Box::new(FirstBinder),
        ),
        PolicyKind::Random => (
            vec![(Box::new(RandomPlugin::new(0x5EED)) as Box<dyn ScorePlugin>, 1.0)],
            Box::new(RandomBinder::new(0xB14D)),
        ),
    };
    let mut sched = Scheduler::new(plugins, binder, &label);
    if let PolicyKind::PwrFgdDynamic { alpha_empty, alpha_full } = kind {
        sched.set_modulator(Box::new(LoadAlphaModulator { alpha_empty, alpha_full }));
    }
    sched
}

fn run_with(
    sched: Scheduler,
    cluster: &ClusterSpec,
    trace: &TraceSpec,
    seed: u64,
    target: f64,
) -> RunResult {
    let dc = cluster.build();
    let workload = trace.synthesize(seed ^ 0x57AB1E).workload();
    let mut sim = Simulation::with_spec(dc, sched, trace, workload, seed);
    sim.record_frag = false;
    sim.run_inflation(target)
}

fn assert_bit_identical(policy: &str, a: &RunResult, b: &RunResult) {
    assert_eq!(a.submitted, b.submitted, "{policy}: submitted diverged");
    assert_eq!(a.scheduled, b.scheduled, "{policy}: scheduled diverged");
    assert_eq!(a.failed, b.failed, "{policy}: failed diverged");
    assert_eq!(
        a.arrived_gpu_units.to_bits(),
        b.arrived_gpu_units.to_bits(),
        "{policy}: arrived units diverged"
    );
    assert_eq!(
        a.allocated_gpu_units.to_bits(),
        b.allocated_gpu_units.to_bits(),
        "{policy}: allocated units diverged"
    );
    assert_eq!(
        a.final_eopc().to_bits(),
        b.final_eopc().to_bits(),
        "{policy}: final EOPC diverged ({} vs {})",
        a.final_eopc(),
        b.final_eopc()
    );
    assert_eq!(
        a.final_grar().to_bits(),
        b.final_grar().to_bits(),
        "{policy}: final GRAR diverged"
    );
}

/// Every non-MIG legacy policy string: the profile-lowered scheduler
/// and the replicated pre-redesign wiring agree bit for bit on a
/// fixed-seed inflation, and the labels are byte-identical.
#[test]
fn legacy_policies_lower_bit_identically() {
    let cluster = ClusterSpec::tiny(6, 4, 1);
    let trace = TraceSpec::default_trace();
    for s in [
        "fgd",
        "pwr",
        "pwrfgd:0.1",
        "pwrfgd:0.5",
        "pwrfgddyn:0.9:0.0",
        "bestfit",
        "dotprod",
        "gpupacking",
        "gpuclustering",
        "firstfit",
        "random",
    ] {
        let kind = PolicyKind::parse(s).expect(s);
        let profile = SchedulerProfile::parse(s).expect(s);
        assert_eq!(profile.label, kind.label(), "{s}: label drifted");
        let lowered = run_with(profile.build().unwrap(), &cluster, &trace, 42, 0.8);
        let reference = run_with(reference_scheduler(kind), &cluster, &trace, 42, 0.8);
        assert!(lowered.submitted > 0, "{s}: empty run");
        assert_bit_identical(s, &lowered, &reference);
    }
}

/// The MIG policy family on a MIG cluster and slice-demand trace.
#[test]
fn mig_policies_lower_bit_identically() {
    let cluster = ClusterSpec::mig_cluster(4, 4, 0);
    let trace = TraceSpec::mig_trace(0.3);
    for s in ["mig-bestfit", "mig-slicefit", "mig-fgd", "mig-pwr", "mig-pwrfgd:0.1"] {
        let kind = PolicyKind::parse(s).expect(s);
        let profile = SchedulerProfile::parse(s).expect(s);
        assert_eq!(profile.label, kind.label(), "{s}: label drifted");
        let lowered = run_with(profile.build().unwrap(), &cluster, &trace, 11, 0.8);
        let reference = run_with(reference_scheduler(kind), &cluster, &trace, 11, 0.8);
        assert!(lowered.scheduled > 0, "{s}: scheduled nothing");
        assert_bit_identical(s, &lowered, &reference);
    }
}

/// The DSL `hook(repartition)` wiring equals `RepeatConfig`'s
/// `mig_repartition` attachment bit for bit (same config, same
/// protocol, counters included).
#[test]
fn dsl_repartition_hook_matches_repeatconfig_attachment() {
    let cluster = ClusterSpec::mig_cluster(2, 2, 0);
    let trace = TraceSpec::mig_trace(0.5);
    let via_cfg = run_repetitions(
        &cluster,
        &trace,
        PolicyKind::MigFgd,
        &RepeatConfig {
            reps: 2,
            base_seed: 7,
            target_ratio: 1.0,
            mig_repartition: true,
            ..Default::default()
        },
    );
    // The same scheduler expressed as a profile with an explicit hook
    // (RepartitionConfig::default() == no params == ∞ threshold).
    let mut profile = PolicyKind::MigFgd.profile();
    profile.hooks.push(("repartition".to_string(), vec![]));
    let via_dsl = run_repetitions(
        &cluster,
        &trace,
        profile,
        &RepeatConfig { reps: 2, base_seed: 7, target_ratio: 1.0, ..Default::default() },
    );
    assert!(via_cfg.iter().map(|r| r.repartitions).sum::<u64>() > 0, "hook never fired");
    for (a, b) in via_cfg.iter().zip(&via_dsl) {
        assert_bit_identical("mig-fgd+repartition", a, b);
        assert_eq!(a.repartitions, b.repartitions);
        assert_eq!(a.proactive_repartitions, b.proactive_repartitions);
        assert_eq!(a.migrated_slices, b.migrated_slices);
    }
}

/// A composite DSL profile — three score objectives plus a load
/// modulator — runs end to end and is seed-deterministic (the
/// acceptance scenario of the redesign).
#[test]
fn composite_dsl_profile_runs_end_to_end() {
    let cluster = ClusterSpec::tiny(6, 4, 1);
    let trace = TraceSpec::default_trace();
    let spec =
        "score(pwr=0.5,fgd=0.375,dotprod=0.125)|bind(weighted:0.5)|mod(loadalpha:0.9:0.05)";
    let run = |seed: u64| {
        let profile = SchedulerProfile::parse(spec).unwrap();
        run_with(profile.build().unwrap(), &cluster, &trace, seed, 0.9)
    };
    let a = run(3);
    let b = run(3);
    assert!(a.scheduled > 0, "composite profile scheduled nothing");
    assert!(a.final_grar() > 0.5, "GRAR collapsed: {}", a.final_grar());
    assert_bit_identical(spec, &a, &b);
    // A MIG composite with slicefit + repartition hook also runs.
    let mig = SchedulerProfile::parse(
        "score(pwr=0.3,fgd=0.5,slicefit=0.2)|bind(weighted:0.3)|hook(repartition:0.5)",
    )
    .unwrap();
    let r = run_with(
        mig.build().unwrap(),
        &ClusterSpec::mig_cluster(2, 2, 0),
        &TraceSpec::mig_trace(0.5),
        7,
        0.8,
    );
    assert!(r.scheduled > 0, "MIG composite scheduled nothing");
}

/// The repartitioner stays usable as a plain value for custom
/// harnesses: attaching the same config through a profile or by hand
/// yields the same counters type (smoke for the PostHook surface).
#[test]
fn repartition_hook_counters_surface() {
    let profile = SchedulerProfile::parse("score(fgd)|bind(weighted:0.0)|hook(repartition)")
        .unwrap();
    let sched = profile.build().unwrap();
    assert_eq!(sched.hook_counter("repartitions"), 0);
    assert_eq!(sched.hook_counter("migrated_slices"), 0);
    // Hand-built equivalent.
    let mut by_hand = Scheduler::from_policy(PolicyKind::Fgd);
    by_hand.add_post_hook(Box::new(MigRepartitioner::new(RepartitionConfig::default())));
    assert_eq!(by_hand.hook_counter("repartitions"), 0);
}
