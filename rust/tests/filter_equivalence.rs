//! Filter-pipeline equivalence regression suite.
//!
//! The filter redesign must be invisible on constraint-free traces: the
//! default plugin chain (`resources` ∧ `gpumodel` ∧ `miglattice` ∧
//! `labels` ∧ `affinity`) replacing the pre-redesign inlined
//! `node.can_fit(task)` call has to produce **bit-identical** fixed-seed
//! runs against a scheduler whose chain is exactly the legacy monolithic
//! `can_fit` — across policies × trace families × seeds, in both
//! simulation loops (inflation and steady-state churn). The PreFilter
//! early-exit is covered by construction: a PreFilter veto can only fire
//! when the node loop would find nothing, so counts and RNG streams
//! cannot drift.
//!
//! The suite also pins the constraint side: at 50% constrained load the
//! pipeline must both keep scheduling and report a nonzero
//! unschedulable-due-to-constraints counter (the `ext-filters`
//! acceptance criterion), and committed placements must respect tenant
//! anti-affinity and spread caps.

use repro::cluster::node::{Node, ResourceView};
use repro::cluster::ClusterSpec;
use repro::sched::filter::{FilterCtx, FilterPlugin};
use repro::sched::SchedulerProfile;
use repro::sim::events::{SteadyConfig, SteadySim};
use repro::sim::{RunResult, Simulation};
use repro::tasks::Task;
use repro::trace::TraceSpec;

/// The pre-redesign Filter phase, verbatim: one monolithic `can_fit`.
struct LegacyCanFit;

impl FilterPlugin for LegacyCanFit {
    fn name(&self) -> &'static str {
        "legacy-canfit"
    }
    fn feasible(&self, _ctx: &FilterCtx, node: &Node, task: &Task) -> bool {
        node.can_fit(task)
    }
}

fn run_inflation(
    policy: &str,
    legacy_filter: bool,
    cluster: &ClusterSpec,
    trace: &TraceSpec,
    seed: u64,
    target: f64,
) -> RunResult {
    let mut sched = SchedulerProfile::parse(policy).unwrap().build().unwrap();
    if legacy_filter {
        sched.set_filters(vec![Box::new(LegacyCanFit)]);
    }
    let dc = cluster.build();
    let workload = trace.synthesize(seed ^ 0x57AB1E).workload();
    let mut sim = Simulation::with_spec(dc, sched, trace, workload, seed);
    sim.record_frag = false;
    sim.run_inflation(target)
}

fn assert_bit_identical(what: &str, a: &RunResult, b: &RunResult) {
    assert_eq!(a.submitted, b.submitted, "{what}: submitted diverged");
    assert_eq!(a.scheduled, b.scheduled, "{what}: scheduled diverged");
    assert_eq!(a.failed, b.failed, "{what}: failed diverged");
    assert_eq!(
        a.allocated_gpu_units.to_bits(),
        b.allocated_gpu_units.to_bits(),
        "{what}: allocated units diverged"
    );
    assert_eq!(
        a.final_eopc().to_bits(),
        b.final_eopc().to_bits(),
        "{what}: final EOPC diverged ({} vs {})",
        a.final_eopc(),
        b.final_eopc()
    );
    assert_eq!(
        a.final_grar().to_bits(),
        b.final_grar().to_bits(),
        "{what}: final GRAR diverged"
    );
}

/// Property sweep: the default filter chain is placement-equivalent to
/// the monolithic `can_fit` on constraint-free random traces — every
/// policy family × trace family × seed must reproduce bit for bit.
#[test]
fn pipeline_matches_can_fit_on_constraint_free_inflation() {
    let cluster = ClusterSpec::tiny(6, 4, 1);
    let traces = [
        TraceSpec::default_trace(),
        TraceSpec::sharing_gpu(1.0),
        TraceSpec::multi_gpu(0.2),
        // The legacy model-pin trace: `gpumodel` must equal can_fit's
        // inline model check.
        TraceSpec::constrained_gpu(0.33),
    ];
    for policy in ["fgd", "pwrfgd:0.1", "bestfit", "dotprod", "firstfit", "random"] {
        for trace in &traces {
            for seed in [1u64, 42] {
                let what = format!("{policy}/{}/seed{seed}", trace.name);
                let pipeline = run_inflation(policy, false, &cluster, trace, seed, 0.7);
                let legacy = run_inflation(policy, true, &cluster, trace, seed, 0.7);
                assert!(pipeline.submitted > 0, "{what}: empty run");
                assert_bit_identical(&what, &pipeline, &legacy);
                assert_eq!(
                    pipeline.constraint_unschedulable, 0,
                    "{what}: constraint counter fired on a constraint-free trace"
                );
            }
        }
    }
}

/// Same equivalence on a MIG cluster with slice demands (the
/// `miglattice` plugin + `resources`' lattice-gated quantity check).
#[test]
fn pipeline_matches_can_fit_on_mig_inflation() {
    let cluster = ClusterSpec::mig_het_cluster(3, 2, 4, 1);
    let trace = TraceSpec::mig_het_trace(0.3, 0.4);
    for policy in ["mig-fgd", "mig-pwrfgd:0.1", "mig-slicefit"] {
        let pipeline = run_inflation(policy, false, &cluster, &trace, 11, 0.8);
        let legacy = run_inflation(policy, true, &cluster, &trace, 11, 0.8);
        assert!(pipeline.scheduled > 0, "{policy}: scheduled nothing");
        assert_bit_identical(policy, &pipeline, &legacy);
    }
}

/// The churn loop (arrivals + departures) through `Scheduler::place`/
/// `release` must agree too — the second simulation loop of the
/// placement-equivalence property.
#[test]
fn pipeline_matches_can_fit_under_churn() {
    let cfg = SteadyConfig {
        mean_interarrival_s: 1.0,
        mean_duration_s: 250.0,
        horizon_s: 2_500.0,
        sample_every_s: 50.0,
        seed: 9,
    };
    let cluster = ClusterSpec::tiny(8, 4, 2);
    let trace = TraceSpec::default_trace();
    let run = |legacy: bool| {
        let mut sched = SchedulerProfile::parse("pwrfgd:0.1").unwrap().build().unwrap();
        if legacy {
            sched.set_filters(vec![Box::new(LegacyCanFit)]);
        }
        let mut sim = SteadySim::new(cluster.build(), sched, &trace, &cfg);
        sim.run(&cfg)
    };
    let a = run(false);
    let b = run(true);
    assert!(a.arrivals > 1_000, "arrivals {}", a.arrivals);
    assert_eq!(a.arrivals, b.arrivals);
    assert_eq!(a.scheduled, b.scheduled);
    assert_eq!(a.failed, b.failed);
    assert_eq!(a.departures, b.departures);
    assert_eq!(
        a.steady_eopc_w.to_bits(),
        b.steady_eopc_w.to_bits(),
        "steady EOPC diverged"
    );
    assert_eq!(a.constraint_unschedulable, 0);
}

/// The `ext-filters` acceptance scenario in miniature: a 50% constrained
/// trace on a small cluster must run end to end, fail some tasks *due to
/// constraints* (nonzero counter, bounded by total failures), and every
/// committed placement must satisfy tenant isolation and spread caps.
#[test]
fn constrained_load_reports_constraint_unschedulable() {
    let cluster = ClusterSpec::tiny(4, 4, 1);
    let trace = TraceSpec::constrained(0.5);
    let r = run_inflation("pwrfgd:0.1", false, &cluster, &trace, 3, 1.0);
    assert!(r.scheduled > 0, "nothing scheduled under constraints");
    assert!(
        r.constraint_unschedulable > 0,
        "50% constrained load never hit a constraint failure"
    );
    assert!(
        r.constraint_unschedulable <= r.failed,
        "constraint failures ({}) exceed total failures ({})",
        r.constraint_unschedulable,
        r.failed
    );
    // Determinism of the constrained path.
    let r2 = run_inflation("pwrfgd:0.1", false, &cluster, &trace, 3, 1.0);
    assert_eq!(r.constraint_unschedulable, r2.constraint_unschedulable);
    assert_bit_identical("constrained-50 determinism", &r, &r2);
}

/// Committed cluster state respects the constraint semantics: no node
/// ever hosts two different tenants, and no node exceeds a spread cap.
#[test]
fn committed_placements_respect_constraints() {
    use repro::trace::SPREAD_MAX_PER_NODE;
    let dc = ClusterSpec::tiny(4, 4, 1).build();
    let trace = TraceSpec::constrained(0.75);
    let sched = SchedulerProfile::parse("pwrfgd:0.1").unwrap().build().unwrap();
    let workload = trace.synthesize(5 ^ 0x57AB1E).workload();
    let mut sim = Simulation::with_spec(dc, sched, &trace, workload, 5);
    sim.record_frag = false;
    sim.run_inflation(1.0);
    for node in &sim.dc.nodes {
        let tenants: Vec<&String> = node
            .class_counts
            .keys()
            .filter(|k| k.starts_with("tenant-"))
            .collect();
        assert!(
            tenants.len() <= 1,
            "node {} hosts multiple tenants: {tenants:?}",
            node.id
        );
        for (key, &count) in &node.class_counts {
            if key.starts_with("spread-") {
                assert!(
                    count <= SPREAD_MAX_PER_NODE,
                    "node {} exceeds spread cap on {key}: {count}",
                    node.id
                );
            }
        }
    }
}
