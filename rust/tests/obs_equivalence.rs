//! Observability equivalence + schema suite (`docs/observability.md`).
//!
//! The obs layer (`rust/src/obs/`) must be invisible when engaged:
//! attaching a decision tracer and enabling phase-latency profiling has
//! to produce **bit-identical** fixed-seed runs against a bare
//! scheduler — across policies × trace families × seeds, in both
//! simulation loops (inflation and steady-state churn, including a DRS
//! diurnal run where hooks actually sleep and wake nodes).
//!
//! The suite also pins the active side: the JSONL event stream
//! round-trips through `util::json` with the documented schema (one
//! `place` event per arrival, one `release` per departure, each
//! self-describing via policy/seed/seq), the registry snapshot agrees
//! with the legacy result-struct counters (the shim contract), and an
//! exercised run's Prometheus exposition covers every catalog key.

use repro::cluster::ClusterSpec;
use repro::obs::{self, DecisionTracer, MetricKind, TraceSink};
use repro::sched::SchedulerProfile;
use repro::sim::events::{SteadyConfig, SteadySim, SteadyResult};
use repro::sim::{RunResult, Simulation};
use repro::trace::TraceSpec;
use repro::util::json::{self, Json};

/// One inflation run; `obs` = attach a memory-sink tracer + profiling.
/// Returns the result and the sink (empty when `obs` is off).
fn run_inflation(
    policy: &str,
    cluster: &ClusterSpec,
    trace: &TraceSpec,
    seed: u64,
    target: f64,
    obs: bool,
) -> (RunResult, TraceSink) {
    let mut sched = SchedulerProfile::parse(policy).unwrap().build().unwrap();
    let sink = TraceSink::memory();
    if obs {
        let label = sched.label().to_string();
        sched.set_tracer(DecisionTracer::new(sink.clone(), &label, seed));
        sched.enable_profiling(true);
    }
    let dc = cluster.build();
    let workload = trace.synthesize(seed ^ 0x57AB1E).workload();
    let mut sim = Simulation::with_spec(dc, sched, trace, workload, seed);
    sim.record_frag = false;
    let out = sim.run_inflation(target);
    sim.sched.trace_flush();
    (out, sink)
}

/// One churn run under the given policy; `obs` as above.
fn run_churn(
    policy: &str,
    cluster: &ClusterSpec,
    trace: &TraceSpec,
    cfg: &SteadyConfig,
    obs: bool,
) -> (SteadyResult, TraceSink) {
    let mut sched = SchedulerProfile::parse(policy).unwrap().build().unwrap();
    let sink = TraceSink::memory();
    if obs {
        let label = sched.label().to_string();
        sched.set_tracer(DecisionTracer::new(sink.clone(), &label, cfg.seed));
        sched.enable_profiling(true);
    }
    let mut sim = SteadySim::new(cluster.build(), sched, trace, cfg);
    let out = sim.run(cfg);
    sim.sched().trace_flush();
    (out, sink)
}

fn assert_bit_identical(what: &str, a: &RunResult, b: &RunResult) {
    assert_eq!(a.submitted, b.submitted, "{what}: submitted diverged");
    assert_eq!(a.scheduled, b.scheduled, "{what}: scheduled diverged");
    assert_eq!(a.failed, b.failed, "{what}: failed diverged");
    assert_eq!(
        a.allocated_gpu_units.to_bits(),
        b.allocated_gpu_units.to_bits(),
        "{what}: allocated units diverged"
    );
    assert_eq!(
        a.final_eopc().to_bits(),
        b.final_eopc().to_bits(),
        "{what}: final EOPC diverged ({} vs {})",
        a.final_eopc(),
        b.final_eopc()
    );
    assert_eq!(
        a.final_grar().to_bits(),
        b.final_grar().to_bits(),
        "{what}: final GRAR diverged"
    );
}

/// Tracing + profiling attached vs bare scheduler: bit-identical
/// inflation runs across policies × traces × seeds, and the traced run
/// emits exactly one `place` event per submission.
#[test]
fn obs_enabled_is_bit_identical_in_inflation() {
    let cluster = ClusterSpec::tiny(6, 4, 1);
    let traces = [TraceSpec::default_trace(), TraceSpec::sharing_gpu(1.0)];
    for policy in ["fgd", "pwrfgd:0.1", "bestfit", "random"] {
        for trace in &traces {
            for seed in [1u64, 42] {
                let what = format!("{policy}/{}/seed{seed}", trace.name);
                let (base, _) = run_inflation(policy, &cluster, trace, seed, 0.7, false);
                let (with, sink) = run_inflation(policy, &cluster, trace, seed, 0.7, true);
                assert!(base.submitted > 0, "{what}: empty run");
                assert_bit_identical(&what, &base, &with);
                let lines = sink.contents().lines().count() as u64;
                assert_eq!(lines, with.submitted, "{what}: trace events ≠ submissions");
            }
        }
    }
}

/// The same pin under steady-state churn — including a DRS diurnal run
/// where hooks drain, sleep and wake nodes mid-trace (hook actions flow
/// into trace events; they must not flow back into decisions).
#[test]
fn obs_enabled_is_bit_identical_under_churn() {
    let cfg = SteadyConfig {
        mean_interarrival_s: 1.0,
        mean_duration_s: 100.0,
        horizon_s: 2_000.0,
        sample_every_s: 50.0,
        seed: 9,
    };
    let cluster = ClusterSpec::tiny(8, 4, 2);
    let cases = [
        ("pwrfgd:0.1", TraceSpec::default_trace()),
        (
            "score(pwr=0.1,fgd=0.7,consolidate=0.2)|bind(weighted:0.1)|hook(drs:80:5)",
            TraceSpec::diurnal_with_period(0.6, 1_000.0),
        ),
    ];
    for (policy, trace) in &cases {
        let (a, _) = run_churn(policy, &cluster, trace, &cfg, false);
        let (b, sink) = run_churn(policy, &cluster, trace, &cfg, true);
        assert!(a.arrivals > 500, "{policy}: arrivals {}", a.arrivals);
        assert_eq!(a.arrivals, b.arrivals, "{policy}: arrivals diverged");
        assert_eq!(a.scheduled, b.scheduled, "{policy}: scheduled diverged");
        assert_eq!(a.failed, b.failed, "{policy}: failed diverged");
        assert_eq!(a.departures, b.departures, "{policy}: departures diverged");
        assert_eq!(a.drs_sleeps, b.drs_sleeps, "{policy}: sleeps diverged");
        assert_eq!(a.drs_wakes, b.drs_wakes, "{policy}: wakes diverged");
        assert_eq!(
            a.steady_eopc_w.to_bits(),
            b.steady_eopc_w.to_bits(),
            "{policy}: steady EOPC diverged"
        );
        // One place event per arrival + one release per departure.
        let lines = sink.contents().lines().count() as u64;
        assert_eq!(lines, b.arrivals + b.departures, "{policy}: event count");
    }
}

/// Every traced line is valid JSON carrying the documented schema:
/// `place` events the full decision anatomy, `release` events the
/// departure, both stamped with policy/seed/seq.
#[test]
fn jsonl_events_roundtrip_with_documented_schema() {
    let cfg = SteadyConfig {
        mean_interarrival_s: 2.0,
        mean_duration_s: 100.0,
        horizon_s: 600.0,
        sample_every_s: 50.0,
        seed: 5,
    };
    let cluster = ClusterSpec::tiny(4, 4, 1);
    let trace = TraceSpec::default_trace();
    let (out, sink) = run_churn("pwrfgd:0.1", &cluster, &trace, &cfg, true);
    assert!(out.departures > 0, "no departures — schema test needs both event kinds");
    let label = SchedulerProfile::parse("pwrfgd:0.1").unwrap().label;
    let text = sink.contents();
    let mut places = 0u64;
    let mut releases = 0u64;
    let mut prev_seq: Option<u64> = None;
    for line in text.lines() {
        let ev = json::parse(line).expect("traced line parses as JSON");
        // The self-describing stamp (one scheduler, so seq is monotone).
        assert_eq!(ev.get("policy").and_then(Json::as_str), Some(label.as_str()));
        assert_eq!(ev.get("seed").and_then(Json::as_u64), Some(5));
        let seq = ev.get("seq").and_then(Json::as_u64).expect("seq");
        assert_eq!(seq, prev_seq.map(|s| s + 1).unwrap_or(0), "seq not monotone");
        prev_seq = Some(seq);
        let task = ev.get("task").expect("task");
        assert!(task.get("id").and_then(Json::as_u64).is_some());
        assert!(task.get("gpu").and_then(Json::as_str).is_some());
        assert!(ev.get("hooks").is_some());
        assert!(ev.get("now").and_then(Json::as_u64).is_some());
        match ev.get("event").and_then(Json::as_str) {
            Some("place") => {
                places += 1;
                let verdict = ev
                    .get("prefilter")
                    .and_then(|p| p.get("verdict"))
                    .and_then(Json::as_str)
                    .expect("prefilter verdict");
                assert!(verdict == "pass" || verdict == "veto");
                assert!(!ev.get("filters").and_then(Json::as_arr).unwrap().is_empty());
                let outcome = ev.get("outcome").and_then(Json::as_str).unwrap();
                match outcome {
                    "placed" => {
                        let bind = ev.get("bind").expect("bind");
                        assert!(bind.get("node").and_then(Json::as_u64).is_some());
                        assert!(bind.get("placement").and_then(Json::as_str).is_some());
                        let scores = ev.get("scores").and_then(Json::as_arr).unwrap();
                        assert!(!scores.is_empty());
                        // Winner first, with per-plugin columns.
                        assert_eq!(scores[0].get("winner"), Some(&Json::Bool(true)));
                        assert!(scores[0].get("per_plugin").is_some());
                        assert!(ev.get("ties").and_then(Json::as_u64).unwrap() >= 1);
                        assert!(ev.get("weights").and_then(Json::as_arr).is_some());
                        assert!(ev.get("tie_seed").and_then(Json::as_u64).is_some());
                    }
                    "failed" => assert!(matches!(ev.get("bind"), Some(Json::Null))),
                    other => panic!("unknown outcome {other}"),
                }
            }
            Some("release") => {
                releases += 1;
                assert!(ev.get("node").and_then(Json::as_u64).is_some());
                assert!(ev.get("placement").and_then(Json::as_str).is_some());
            }
            other => panic!("unknown event kind {other:?}"),
        }
    }
    assert_eq!(places, out.arrivals);
    assert_eq!(releases, out.departures);
}

/// The shim contract: the legacy result-struct counters and the
/// registry snapshot are two views of the same numbers, and an
/// exercised run's Prometheus exposition covers every catalog key.
#[test]
fn registry_snapshot_agrees_with_result_counters_and_covers_catalog() {
    let cluster = ClusterSpec::tiny(4, 4, 1);
    let trace = TraceSpec::default_trace();
    let mut sched = SchedulerProfile::parse("pwrfgd:0.1").unwrap().build().unwrap();
    sched.enable_profiling(true);
    let dc = cluster.build();
    let workload = trace.synthesize(7 ^ 0x57AB1E).workload();
    let mut sim = Simulation::with_spec(dc, sched, &trace, workload, 7);
    sim.record_frag = false;
    let out = sim.run_inflation(1.2);
    let m = sim.sched.metrics();
    assert_eq!(m.counter("sched_places"), out.scheduled);
    assert_eq!(m.counter("sched_failures"), out.failed);
    assert_eq!(m.counter("constraint_unschedulable"), out.constraint_unschedulable);
    assert_eq!(m.counter("repartitions"), out.repartitions);
    assert_eq!(m.counter("drs_sleeps"), out.drs_sleeps);
    assert_eq!(sim.sched.constraint_unschedulable(), m.counter("constraint_unschedulable"));
    // Profiling accumulated every phase histogram.
    for key in ["phase_filter_ns", "phase_score_ns", "phase_bind_ns", "phase_hooks_ns", "place_ns"]
    {
        assert!(
            m.histogram(key).unwrap().count() > 0,
            "{key} empty after a profiled run"
        );
    }
    // The exposition covers the whole catalog with well-formed lines.
    let text = m.to_prometheus("repro_");
    for (key, kind, _) in obs::catalog() {
        let ty = match kind {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "summary",
        };
        assert!(
            text.contains(&format!("# TYPE repro_{key} {ty}")),
            "catalog key {key} missing from exposition"
        );
    }
    for line in text.lines() {
        assert!(
            line.starts_with('#') || line.split_whitespace().count() == 2,
            "malformed exposition line: {line}"
        );
    }
}
