//! Integration tests for the MIG partitioning subsystem: lattice
//! round-trips through the full allocation stack, the deterministic
//! end-to-end policy comparison the `ext-mig` experiment is built on
//! (MIG-PWR⊕FGD must not draw more power than MIG-BestFit), and the
//! online repartitioner under churn.

use repro::cluster::mig::{MigGpu, MigLattice, MigProfile};
use repro::cluster::node::{Placement, ResourceView};
use repro::cluster::ClusterSpec;
use repro::metrics::{average_on_grid, capacity_grid, Column};
use repro::sched::policies::{MigRepartitioner, RepartitionConfig};
use repro::sched::{PolicyKind, Scheduler};
use repro::sim::{run_repetitions, RepeatConfig, Simulation};
use repro::tasks::{GpuDemand, Task, Workload};
use repro::trace::TraceSpec;
use repro::util::rng::Rng;

/// Random alloc/release interleavings through Node+Datacenter: every
/// resident profile set stays within the 7-slice lattice, the
/// `gpu_alloc` mirror matches the partition state, and draining
/// returns the cluster to pristine.
#[test]
fn lattice_roundtrips_through_alloc_release() {
    let mut dc = ClusterSpec::mig_cluster(2, 2, 0).build();
    let mut rng = Rng::new(0x519);
    let mut live: Vec<(Task, usize, Placement)> = Vec::new();
    for step in 0..600 {
        if !live.is_empty() && rng.bernoulli(0.4) {
            let (task, node, placement) = live.swap_remove(rng.below(live.len()));
            dc.deallocate(&task, node, &placement);
        } else {
            let p = *rng.choice(&MigProfile::ALL);
            let task = Task::new(step, 2.0, 512.0, GpuDemand::Mig(p));
            let node = rng.below(dc.nodes.len());
            let mut placements = dc.nodes[node].candidate_placements(&task);
            if placements.is_empty() {
                continue;
            }
            let placement = placements.swap_remove(rng.below(placements.len()));
            dc.allocate(&task, node, &placement);
            live.push((task, node, placement));
        }
        // Invariants after every operation.
        for n in &dc.nodes {
            let migs = n.mig.as_ref().unwrap();
            for (g, mg) in migs.iter().enumerate() {
                let sum: u32 = mg.instances.iter().map(|i| i.profile.slices() as u32).sum();
                assert!(sum <= 7, "step {step}: {sum} slices resident");
                assert_eq!(mg.used_slices() as u32, sum, "mask drifted from instances");
                assert!((n.gpu_alloc[g] - mg.alloc_fraction()).abs() < 1e-12);
            }
        }
        let (gpu, cpu) = dc.recompute_caches();
        assert!((gpu - dc.gpu_allocated_units()).abs() < 1e-6);
        assert!((cpu - dc.cpu_allocated_units()).abs() < 1e-6);
    }
    for (task, node, placement) in live.drain(..) {
        dc.deallocate(&task, node, &placement);
    }
    for n in &dc.nodes {
        assert!(n.mig.as_ref().unwrap().iter().all(|m| m.mask == 0 && m.instances.is_empty()));
        assert!(n.gpu_alloc.iter().all(|&a| a == 0.0));
    }
}

/// Every MIG policy binds only legal slice placements across a full
/// inflation, and the slice-aware scheduler stays deterministic.
#[test]
fn mig_policies_bind_legal_placements_deterministically() {
    for policy in [
        PolicyKind::MigBestFit,
        PolicyKind::MigSliceFit,
        PolicyKind::MigFgd,
        PolicyKind::MigPwr,
        PolicyKind::MigPwrFgd { alpha: 0.1 },
    ] {
        let spec = TraceSpec::mig_trace(0.3);
        let run = |seed: u64| {
            let dc = ClusterSpec::mig_cluster(6, 4, 1).build();
            let workload = spec.synthesize(seed ^ 0x57AB1E).workload();
            let sched = Scheduler::from_policy(policy);
            let mut sim = Simulation::with_spec(dc, sched, &spec, workload, seed);
            sim.record_frag = false;
            sim.run_inflation(0.9)
        };
        let a = run(3);
        let b = run(3);
        assert_eq!(a.submitted, b.submitted, "{policy:?} not deterministic");
        assert!((a.final_eopc() - b.final_eopc()).abs() < 1e-9);
        assert!(a.scheduled > 0, "{policy:?} scheduled nothing");
        assert!(a.final_grar() > 0.5, "{policy:?} GRAR {}", a.final_grar());
    }
}

/// The acceptance comparison behind `ext-mig`: with deterministic
/// seeds, MIG-PWR⊕FGD's final EOPC must not exceed MIG-BestFit's
/// (power-aware slice packing concentrates load; best-fit's k8s random
/// tie-break spreads it over idle GPUs).
#[test]
fn mig_pwrfgd_beats_mig_bestfit_on_final_eopc() {
    let cluster = ClusterSpec::mig_cluster(12, 8, 2);
    let spec = TraceSpec::mig_trace(0.3);
    let cfg = RepeatConfig {
        reps: 3,
        base_seed: 42,
        target_ratio: 0.7,
        record_frag: true,
        mig_repartition: true,
        ..Default::default()
    };
    let grid = capacity_grid(0.7, 0.1);
    let mean_final = |policy: PolicyKind| {
        let runs = run_repetitions(&cluster, &spec, policy, &cfg);
        let series: Vec<_> = runs.into_iter().map(|r| r.series).collect();
        let eopc = average_on_grid(&series, Column::Eopc, &grid);
        let frag = average_on_grid(&series, Column::Frag, &grid);
        (eopc, frag)
    };
    let (bestfit, _) = mean_final(PolicyKind::MigBestFit);
    let (combo, combo_frag) = mean_final(PolicyKind::MigPwrFgd { alpha: 0.1 });
    let (b, c) = (*bestfit.last().unwrap(), *combo.last().unwrap());
    assert!(
        c <= b * 1.001,
        "MIG-PWR⊕FGD final EOPC {c:.0} W should not exceed MIG-BestFit {b:.0} W"
    );
    // Mid-load the gap must be strict: consolidation leaves whole GPUs idle.
    let mid = grid.iter().position(|&x| (x - 0.4).abs() < 1e-9).unwrap();
    assert!(
        combo[mid] < bestfit[mid],
        "mid-load: combo {} vs bestfit {}",
        combo[mid],
        bestfit[mid]
    );
    // The slice-level fragmentation series is recorded and non-trivial.
    assert!(combo_frag.iter().any(|&f| f > 0.0), "frag series all zero");
}

/// Repartitioning helps a fragmentation-prone mix: with the same
/// seeds, enabling the repartitioner must actually fire on a tiny,
/// easily-fragmented cluster, and must not meaningfully lower GRAR
/// (downstream trajectories differ, so allow sub-point noise).
#[test]
fn repartitioner_fires_and_never_hurts_grar() {
    let cluster = ClusterSpec::mig_cluster(2, 2, 0);
    let spec = TraceSpec::mig_trace(0.5);
    let run = |repartition: bool| {
        let cfg = RepeatConfig {
            reps: 3,
            base_seed: 7,
            target_ratio: 1.0,
            mig_repartition: repartition,
            ..Default::default()
        };
        run_repetitions(&cluster, &spec, PolicyKind::MigFgd, &cfg)
    };
    let off = run(false);
    let on = run(true);
    let grar = |rs: &[repro::sim::RunResult]| {
        rs.iter().map(|r| r.final_grar()).sum::<f64>() / rs.len() as f64
    };
    assert!(on.iter().map(|r| r.repartitions).sum::<u64>() > 0, "repartitioner never fired");
    assert!(off.iter().all(|r| r.repartitions == 0));
    assert!(
        grar(&on) >= grar(&off) - 0.01,
        "repartitioning lowered GRAR: {} vs {}",
        grar(&on),
        grar(&off)
    );
}

/// Edge cases of the per-GPU primitives on full, empty and
/// checkerboard masks (beyond the round-trips pinned above):
/// `repack_plan`, `release(profile, None)` and `free_starts`.
#[test]
fn gpu_primitives_on_full_empty_and_checkerboard_masks() {
    // --- Empty GPU ---
    let empty = MigGpu::new();
    for &p in MigLattice::A100.profiles() {
        // Every legal start is free; repack is a zero-move no-op plan.
        assert_eq!(empty.free_starts(p), p.legal_starts().to_vec());
        let (plan, moved) = empty.repack_plan(p).expect("fits on empty");
        assert!(plan.is_empty());
        assert_eq!(moved, 0);
    }
    let mut e = MigGpu::new();
    assert!(!e.release(MigProfile::P1g, None), "release on empty must fail");
    assert_eq!(e, MigGpu::new());

    // --- Full GPU (7g) ---
    let mut full = MigGpu::new();
    assert!(full.place(MigProfile::P7g, 0));
    for &p in MigLattice::A100.profiles() {
        assert!(full.free_starts(p).is_empty());
        assert!(full.repack_plan(p).is_none(), "{p} cannot fit a full GPU");
    }
    assert!(!full.release(MigProfile::P4g, None), "wrong-profile release");
    assert!(full.release(MigProfile::P7g, None));
    assert_eq!(full.used_slices(), 0);

    // --- Checkerboard: 1g at starts 0, 2, 4, 6 (mask 0b101_0101) ---
    let mut cb = MigGpu::new();
    for s in [0u8, 2, 4, 6] {
        assert!(cb.place(MigProfile::P1g, s));
    }
    assert_eq!(cb.mask, 0b101_0101);
    assert_eq!(cb.free_starts(MigProfile::P1g), vec![1, 3, 5]);
    // No aligned 2g window is free, but 3 slices are: only a repack
    // can serve a 2g.
    assert!(cb.free_starts(MigProfile::P2g).is_empty());
    let (plan, moved) = cb.repack_plan(MigProfile::P2g).expect("3 free slices");
    assert!(moved > 0);
    // 4g cannot fit 3 free slices even with a repack.
    assert!(cb.repack_plan(MigProfile::P4g).is_none());
    cb.apply_repack(&plan);
    let s = cb.can_place(MigProfile::P2g).expect("open after repack");
    assert!(cb.place(MigProfile::P2g, s));
    assert_eq!(cb.free_slices(), 1);
    // By-profile release stays fungible after the repack.
    for _ in 0..4 {
        assert!(cb.release(MigProfile::P1g, None));
    }
    assert!(!cb.release(MigProfile::P1g, None));
    assert_eq!(cb.used_slices(), 2); // the 2g remains

    // --- A30 checkerboard: 1g at starts 0 and 2 (mask 0b0101) ---
    let mut cb = MigGpu::with_lattice(MigLattice::A30);
    assert!(cb.place(MigProfile::A30P1g, 0));
    assert!(cb.place(MigProfile::A30P1g, 2));
    assert_eq!(cb.free_starts(MigProfile::A30P1g), vec![1, 3]);
    assert!(cb.free_starts(MigProfile::A30P2g).is_empty());
    let (plan, moved) = cb.repack_plan(MigProfile::A30P2g).expect("2 free slices");
    assert!(moved > 0);
    assert!(cb.repack_plan(MigProfile::A30P4g).is_none());
    cb.apply_repack(&plan);
    assert!(cb.can_place(MigProfile::A30P2g).is_some());
}

/// Regression: the default (∞) fragmentation threshold reproduces the
/// PR 1 failure-only repartitioner exactly — byte-identical counters on
/// a fixed seed — and deterministic-seed runs pin the counters across
/// repeated invocations. A finite threshold on the same seeds switches
/// the proactive trigger on.
#[test]
fn threshold_infinity_matches_failure_only_repartitioner() {
    let cluster = ClusterSpec::mig_cluster(2, 2, 0);
    let spec = TraceSpec::mig_trace(0.5);
    let run = |threshold: f64| {
        let cfg = RepeatConfig {
            reps: 3,
            base_seed: 7,
            target_ratio: 1.0,
            mig_repartition: true,
            mig_frag_threshold: threshold,
            ..Default::default()
        };
        run_repetitions(&cluster, &spec, PolicyKind::MigFgd, &cfg)
    };
    // PR 1 semantics: RepartitionConfig::default() is failure-only; a
    // run with an explicit ∞ threshold must be byte-identical to it.
    let default_cfg = run(RepartitionConfig::default().frag_threshold);
    let infinite = run(f64::INFINITY);
    assert_eq!(default_cfg.len(), infinite.len());
    for (a, b) in default_cfg.iter().zip(&infinite) {
        assert_eq!(a.repartitions, b.repartitions);
        assert_eq!(a.proactive_repartitions, b.proactive_repartitions);
        assert_eq!(a.migrated_slices, b.migrated_slices);
        assert_eq!(a.submitted, b.submitted);
        assert_eq!(a.scheduled, b.scheduled);
        assert_eq!(a.failed, b.failed);
        assert_eq!(b.proactive_repartitions, 0, "∞ threshold must never fire proactively");
    }
    // Deterministic seeds pin the counters: re-running is identical.
    let again = run(f64::INFINITY);
    for (a, b) in infinite.iter().zip(&again) {
        assert_eq!(a.repartitions, b.repartitions);
        assert_eq!(a.migrated_slices, b.migrated_slices);
    }
    // The failure-only runs do repartition on this fragmentation-prone
    // mix — the regression baseline is non-trivial.
    assert!(infinite.iter().map(|r| r.repartitions).sum::<u64>() > 0);
    // Under churn (departures rip holes into the lattice) a finite
    // threshold fires the proactive trigger; ∞ still never does.
    use repro::sim::events::{SteadyConfig, SteadySim};
    let churn = |threshold: f64| {
        let cfg = SteadyConfig {
            mean_interarrival_s: 1.0,
            mean_duration_s: 300.0,
            horizon_s: 3_000.0,
            sample_every_s: 100.0,
            seed: 7,
        };
        let mut sched = Scheduler::from_policy(PolicyKind::MigFgd);
        sched.add_post_hook(Box::new(MigRepartitioner::new(
            RepartitionConfig::with_threshold(threshold),
        )));
        let mut sim = SteadySim::new(cluster.build(), sched, &spec, &cfg);
        sim.run(&cfg)
    };
    let with_proactive = churn(0.5);
    assert!(
        with_proactive.proactive_repartitions > 0,
        "finite threshold never fired proactively under churn"
    );
    let without = churn(f64::INFINITY);
    assert_eq!(without.proactive_repartitions, 0);
}

/// Heterogeneous-fleet end to end (the `ext-mig-het` scenario): mixed
/// A100+A30 inflation schedules demand on both lattices, stays
/// deterministic per seed, and fills the per-lattice metric columns.
#[test]
fn het_fleet_inflation_reports_per_lattice_series() {
    let cluster = ClusterSpec::mig_het_cluster(3, 3, 4, 1);
    let spec = TraceSpec::mig_het_trace(0.3, 0.4);
    let run = |seed: u64| {
        let dc = cluster.build();
        let workload = spec.synthesize(seed ^ 0x57AB1E).workload();
        let mut sched = Scheduler::from_policy(PolicyKind::MigPwrFgd { alpha: 0.1 });
        sched.add_post_hook(Box::new(MigRepartitioner::new(
            RepartitionConfig::with_threshold(0.5),
        )));
        let mut sim = Simulation::with_spec(dc, sched, &spec, workload, seed);
        sim.record_frag = true;
        sim.run_inflation(0.8)
    };
    let a = run(11);
    let b = run(11);
    assert_eq!(a.submitted, b.submitted, "het inflation not deterministic");
    assert!((a.final_eopc() - b.final_eopc()).abs() < 1e-9);
    assert!(a.scheduled > 0);
    assert!(a.final_grar() > 0.5, "GRAR {}", a.final_grar());
    let last = a.series.last().unwrap();
    // Per-lattice EOPC decomposes the fleet's GPU-node power: both
    // sides are live and sum to less than the total (CPU-only nodes).
    assert!(last.eopc_a100 > 0.0 && last.eopc_a30 > 0.0);
    assert!(last.eopc_a100 + last.eopc_a30 <= last.eopc + 1e-9);
    assert!((0.0..=1.0 + 1e-9).contains(&last.grar_a100), "{}", last.grar_a100);
    assert!((0.0..=1.0 + 1e-9).contains(&last.grar_a30), "{}", last.grar_a30);
    // The slice-frag series is recorded for both lattices at some point.
    assert!(a.series.points.iter().any(|p| p.frag_a100 > 0.0));
    assert!(a.series.points.iter().any(|p| p.frag_a30 > 0.0));
}

/// Direct defrag scenario through the scheduler: a lattice-blocked 4g
/// becomes placeable after one repack, and the migration budget is
/// accounted.
#[test]
fn scheduler_level_defrag_unblocks_a_4g() {
    let mut dc = ClusterSpec::mig_cluster(1, 1, 0).build();
    let w = Workload::default();
    // Fragment the single GPU: 1g at slices 1 and 3 (4 slices free, but
    // the 0-3 window for a 4g is broken).
    for (id, start) in [(1u64, 1u8), (2, 3)] {
        let t = Task::new(id, 1.0, 256.0, GpuDemand::Mig(MigProfile::P1g));
        dc.allocate(&t, 0, &Placement::MigSlice { gpu: 0, start });
    }
    let mut sched = Scheduler::from_policy(PolicyKind::MigPwrFgd { alpha: 0.1 });
    let t4 = Task::new(3, 2.0, 512.0, GpuDemand::Mig(MigProfile::P4g));
    assert!(!dc.nodes[0].can_fit(&t4));
    assert!(sched.schedule(&dc, &w, &t4).is_none());
    let mut rp = MigRepartitioner::new(RepartitionConfig::default());
    let node = rp.try_make_room(&mut dc, &t4).expect("repack opens the 0-3 window");
    sched.notify_node_changed(node);
    let d = sched.schedule(&dc, &w, &t4).expect("4g fits after defrag");
    assert!(dc.nodes[d.node].placement_fits(&t4, &d.placement));
    dc.allocate(&t4, d.node, &d.placement);
    assert_eq!(rp.stats.repartitions, 1);
    assert!(rp.stats.migrated_slices >= 1);
    assert!((dc.nodes[0].gpu_alloc[0] - 6.0 / 7.0).abs() < 1e-9);
}
