//! End-to-end integration over the whole L3 stack: simulator + policies
//! + metrics, checking the qualitative results the paper reports —
//! policy orderings, the savings/GRAR trade-off and metric sanity —
//! on a scaled-down (but mix-faithful) cluster.

use repro::cluster::ClusterSpec;
use repro::metrics::{average_on_grid, capacity_grid, savings_pct, Column};
use repro::sched::PolicyKind;
use repro::sim::{run_repetitions, RepeatConfig, Simulation};
use repro::trace::TraceSpec;
use repro::sched::Scheduler;

fn cfg(reps: usize) -> RepeatConfig {
    RepeatConfig { reps, base_seed: 42, target_ratio: 1.0, ..Default::default() }
}

fn eopc_and_grar(
    cluster: &ClusterSpec,
    trace: &TraceSpec,
    policy: PolicyKind,
    reps: usize,
    grid: &[f64],
) -> (Vec<f64>, Vec<f64>) {
    let runs = run_repetitions(cluster, trace, policy, &cfg(reps));
    let series: Vec<_> = runs.into_iter().map(|r| r.series).collect();
    (
        average_on_grid(&series, Column::Eopc, grid),
        average_on_grid(&series, Column::Grar, grid),
    )
}

/// The headline (Figs. 2–3): PWR-weighted combinations save substantial
/// power vs plain FGD in the mid-load region while keeping GRAR ≈ 1.
#[test]
fn pwr_combo_saves_power_at_mid_load() {
    let cluster = ClusterSpec::paper_scaled(0.08);
    let trace = TraceSpec::default_trace();
    let grid = capacity_grid(1.0, 0.1);
    let (fgd, fgd_grar) = eopc_and_grar(&cluster, &trace, PolicyKind::Fgd, 3, &grid);
    let (combo, combo_grar) =
        eopc_and_grar(&cluster, &trace, PolicyKind::PwrFgd { alpha: 0.1 }, 3, &grid);
    let savings = savings_pct(&fgd, &combo);
    // Mid-load mean savings must be clearly positive (paper: >13% at
    // full scale; scaled clusters damp the magnitude, not the sign).
    let mid: Vec<f64> = grid
        .iter()
        .zip(&savings)
        .filter(|(&x, _)| (0.2..=0.7).contains(&x))
        .map(|(_, &s)| s)
        .collect();
    let mean = repro::util::stats::mean(&mid);
    assert!(mean > 2.0, "mid-load savings {mean:.2}% (series {savings:?})");
    // And GRAR stays perfect in that region for both (paper §VI-B:
    // no scheduling failures before ~88% capacity).
    for (i, &x) in grid.iter().enumerate() {
        if x <= 0.7 {
            assert!(fgd_grar[i] > 0.999, "FGD GRAR {} at x={x}", fgd_grar[i]);
            assert!(combo_grar[i] > 0.99, "combo GRAR {} at x={x}", combo_grar[i]);
        }
    }
}

/// Pure PWR saves the most power but fails earlier (paper Fig. 2):
/// its final GRAR must be the worst of {FGD, combo, PWR}.
#[test]
fn pure_pwr_trades_grar_for_power() {
    let cluster = ClusterSpec::paper_scaled(0.08);
    let trace = TraceSpec::default_trace();
    let grid = capacity_grid(1.0, 0.25);
    let (fgd, fgd_grar) = eopc_and_grar(&cluster, &trace, PolicyKind::Fgd, 3, &grid);
    let (pwr, pwr_grar) = eopc_and_grar(&cluster, &trace, PolicyKind::Pwr, 3, &grid);
    // At half load PWR draws less power...
    assert!(pwr[2] < fgd[2], "PWR {} vs FGD {} at x=0.5", pwr[2], fgd[2]);
    // ...but ends with a worse allocation ratio.
    assert!(
        pwr_grar.last().unwrap() < fgd_grar.last().unwrap(),
        "PWR GRAR {:?} should trail FGD {:?}",
        pwr_grar.last(),
        fgd_grar.last()
    );
}

/// FGD must beat the naive baselines on final GRAR (paper Fig. 7 rank).
#[test]
fn fgd_beats_naive_baselines_on_grar() {
    let cluster = ClusterSpec::paper_scaled(0.06);
    let trace = TraceSpec::default_trace();
    let run_final_grar = |p: PolicyKind| {
        let runs = run_repetitions(&cluster, &trace, p, &cfg(3));
        repro::util::stats::mean(&runs.iter().map(|r| r.final_grar()).collect::<Vec<_>>())
    };
    let fgd = run_final_grar(PolicyKind::Fgd);
    let random = run_final_grar(PolicyKind::Random);
    let firstfit = run_final_grar(PolicyKind::FirstFit);
    assert!(fgd > random, "FGD {fgd} vs Random {random}");
    assert!(fgd + 0.02 > firstfit, "FGD {fgd} vs FirstFit {firstfit}");
}

/// Sharing-heavy workloads: every policy still schedules, and the
/// sharing-GPU trace actually shifts demand to fractional tasks.
#[test]
fn sharing_trace_end_to_end() {
    let cluster = ClusterSpec::paper_scaled(0.06);
    let trace = TraceSpec::sharing_gpu(1.0);
    let runs = run_repetitions(&cluster, &trace, PolicyKind::PwrFgd { alpha: 0.1 }, &cfg(2));
    for r in &runs {
        assert!(r.scheduled > 0);
        assert!(r.final_grar() > 0.8, "GRAR {}", r.final_grar());
    }
}

/// Constrained trace: tasks pinned to scarce models fail earlier, but
/// the simulator must stay consistent (failures counted, GRAR < 1).
#[test]
fn constrained_trace_end_to_end() {
    let cluster = ClusterSpec::paper_scaled(0.06);
    let trace = TraceSpec::constrained_gpu(0.33);
    let runs = run_repetitions(&cluster, &trace, PolicyKind::Fgd, &cfg(2));
    for r in &runs {
        assert_eq!(r.submitted, r.scheduled + r.failed);
        assert!(r.final_grar() <= 1.0);
    }
}

/// Determinism across the full stack: same seeds ⇒ identical series.
#[test]
fn full_stack_determinism() {
    let cluster = ClusterSpec::paper_scaled(0.05);
    let trace = TraceSpec::default_trace();
    let a = run_repetitions(&cluster, &trace, PolicyKind::PwrFgd { alpha: 0.2 }, &cfg(2));
    let b = run_repetitions(&cluster, &trace, PolicyKind::PwrFgd { alpha: 0.2 }, &cfg(2));
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.submitted, rb.submitted);
        assert_eq!(ra.failed, rb.failed);
        assert!((ra.final_eopc() - rb.final_eopc()).abs() < 1e-9);
    }
}

/// Departures: allocate, then release everything through the simulator
/// API; the cluster must return to its idle power.
#[test]
fn power_returns_to_idle_after_departures() {
    let dc = ClusterSpec::tiny(4, 4, 1).build();
    let idle = repro::power::p_datacenter(&dc);
    let trace = TraceSpec::default_trace();
    let workload = trace.synthesize(3).workload();
    let sched = Scheduler::from_policy(PolicyKind::PwrFgd { alpha: 0.1 });
    let mut sim = Simulation::with_spec(dc, sched, &trace, workload, 5);
    let mut placed = Vec::new();
    let mut sampler = TraceSpec::default_trace().sampler(5);
    for _ in 0..30 {
        let task = sampler.next_task();
        if let Some(d) = sim.sched.schedule(&sim.dc, &sim.workload, &task) {
            sim.dc.allocate(&task, d.node, &d.placement);
            sim.sched.notify_node_changed(d.node);
            placed.push((task, d));
        }
    }
    assert!(repro::power::p_datacenter(&sim.dc) > idle);
    for (task, d) in placed.into_iter().rev() {
        sim.dc.deallocate(&task, d.node, &d.placement);
        sim.sched.notify_node_changed(d.node);
    }
    let back = repro::power::p_datacenter(&sim.dc);
    assert!((back - idle).abs() < 1e-6, "idle {idle} vs after-release {back}");
}
