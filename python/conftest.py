"""Make `pytest python/tests/` work from the repo root by putting the
`python/` directory (holding the `compile` and `tests` packages) on
sys.path."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
