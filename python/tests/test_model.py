"""L2 correctness: the full scoring graph — feasibility filter, power
deltas, k8s normalization, α-combination and GPU binding — checked
against brute-force python and against itself (Pallas vs ref kernel)."""

import math

import numpy as np
import pytest

from compile.model import NEG_INF_SCORE, score_cluster
from tests.helpers import make_classes, make_cluster, make_task

ALPHA = np.array([0.1], dtype=np.float32)


def run(gpu_free, node_aux, classes, task, alpha=ALPHA, use_pallas=False):
    s, b, f = score_cluster(
        gpu_free, node_aux, classes, task, alpha, use_pallas=use_pallas, block_n=16
    )
    return np.asarray(s), np.asarray(b), np.asarray(f)


def brute_force_feasible(gpu_free, node_aux, task):
    """Independent python reimplementation of Cond. 1–3 + constraint."""
    n, g = gpu_free.shape
    out = np.zeros(n)
    for i in range(n):
        cpu_free, mem_free, _, model = node_aux[i, :4]
        if cpu_free < 0:
            continue
        if task[0] > cpu_free + 1e-6 or task[1] > mem_free + 1e-6:
            continue
        if task[2] == 0:
            out[i] = 1.0
            continue
        if model < 0:
            continue
        if task[6] >= 0 and abs(task[6] - model) > 0.5:
            continue
        frees = [gpu_free[i, j] for j in range(g) if gpu_free[i, j] >= 0]
        if task[3] > 0:  # fractional
            ok = any(fr >= task[2] - 1e-6 for fr in frees)
        else:  # whole
            ok = sum(1 for fr in frees if fr >= 1.0 - 1e-6) >= task[2] - 1e-6
        out[i] = 1.0 if ok else 0.0
    return out


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("kind", [0, 1, 2])
def test_feasibility_matches_bruteforce(seed, kind):
    rng = np.random.default_rng(seed)
    gpu_free, node_aux = make_cluster(rng, n=32, g=6, n_real=30)
    classes = make_classes(rng, m=16)
    task = make_task(rng, kind=kind)
    _, _, feas = run(gpu_free, node_aux, classes, task)
    np.testing.assert_array_equal(feas, brute_force_feasible(gpu_free, node_aux, task))


@pytest.mark.parametrize("seed", range(4))
def test_pallas_and_ref_graphs_agree(seed):
    """The whole L2 graph must be identical whichever L1 backs it."""
    rng = np.random.default_rng(100 + seed)
    gpu_free, node_aux = make_cluster(rng, n=32, g=8)
    classes = make_classes(rng, m=16)
    task = make_task(rng)
    sp, bp, fp = run(gpu_free, node_aux, classes, task, use_pallas=True)
    sr, br, fr = run(gpu_free, node_aux, classes, task, use_pallas=False)
    np.testing.assert_allclose(sp, sr, rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(bp, br)
    np.testing.assert_array_equal(fp, fr)


def test_scores_normalized_0_100():
    rng = np.random.default_rng(7)
    gpu_free, node_aux = make_cluster(rng, n=32, g=4)
    classes = make_classes(rng, m=8)
    task = make_task(rng, kind=1)
    score, _, feas = run(gpu_free, node_aux, classes, task)
    fs = score[feas > 0.5]
    assert fs.size > 0
    assert fs.min() >= -1e-3 and fs.max() <= 100.0 + 1e-3
    assert np.all(score[feas < 0.5] == NEG_INF_SCORE)


def test_alpha_extremes_pick_different_winners():
    """Construct a state where PWR and FGD disagree and check the
    α-extremes switch winners. Task: one whole GPU. Node 0 (T4, 60 W
    wake) has exactly 4 free GPUs — taking one strands the node for the
    whole-4 workload class (big ΔF). Node 1 (G3, 350 W wake) has 8 free
    GPUs — taking one keeps the class schedulable (ΔF 0) but costs far
    more power."""
    gpu_free = np.array(
        [[1.0, 1.0, 1.0, 1.0, -1.0, -1.0, -1.0, -1.0],
         [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]],
        dtype=np.float32,
    )
    node_aux = np.array(
        [
            [64.0, 1e6, 0.0, 3.0, 10.0, 70.0],   # T4 node
            [128.0, 1e6, 0.0, 6.0, 50.0, 400.0],  # G3 node
        ],
        dtype=np.float32,
    )
    classes = np.zeros((1, 7), dtype=np.float32)
    classes[0] = [1.0, 0.0, 4.0, 0.0, 1.0, 1.0, -1.0]  # whole-4 class
    task = np.array([1.0, 0.0, 1.0, 0.0, 1.0, 1.0, -1.0, 0.0], dtype=np.float32)

    s_pwr, _, _ = run(gpu_free, node_aux, classes, task, alpha=np.array([1.0], np.float32))
    s_fgd, _, _ = run(gpu_free, node_aux, classes, task, alpha=np.array([0.0], np.float32))
    assert np.argmax(s_pwr) == 0, f"PWR should pick the cheap T4 node: {s_pwr}"
    assert np.argmax(s_fgd) == 1, f"FGD should protect the 4-GPU node: {s_fgd}"
    # A balanced α must sit between the extremes (both normalized 0/100).
    s_mid, _, _ = run(gpu_free, node_aux, classes, task, alpha=np.array([0.5], np.float32))
    assert s_mid[0] == pytest.approx(50.0) and s_mid[1] == pytest.approx(50.0)


def test_power_delta_consolidation():
    """Pure PWR: sharing an already-active GPU beats waking an idle one
    on an otherwise identical node."""
    gpu_free = np.array([[0.5, 1.0], [1.0, 1.0]], dtype=np.float32)
    node_aux = np.array(
        [
            [94.0, 1e6, 2.0, 5.0, 30.0, 150.0],  # node 0 has an active GPU
            [96.0, 1e6, 0.0, 5.0, 30.0, 150.0],
        ],
        dtype=np.float32,
    )
    classes = make_classes(np.random.default_rng(0), m=4)
    task = np.array([1.0, 0.0, 0.25, 1.0, 0.0, 0.0, -1.0, 0.0], dtype=np.float32)
    score, best_gpu, _ = run(gpu_free, node_aux, classes, task, alpha=np.array([1.0], np.float32))
    assert np.argmax(score) == 0
    assert best_gpu[0] == 0  # the occupied GPU, not the idle one


def test_whole_task_best_gpu_is_minus_one():
    rng = np.random.default_rng(3)
    gpu_free, node_aux = make_cluster(rng, n=16, g=4, cpu_only_frac=0.0)
    classes = make_classes(rng, m=8)
    task = make_task(rng, kind=2)
    _, best_gpu, feas = run(gpu_free, node_aux, classes, task)
    assert np.all(best_gpu == -1.0)


def test_all_infeasible_cluster():
    gpu_free = np.full((4, 2), -1.0, dtype=np.float32)
    node_aux = np.zeros((4, 6), dtype=np.float32)
    node_aux[:, 0] = -1.0  # all padding
    classes = make_classes(np.random.default_rng(0), m=4)
    task = make_task(np.random.default_rng(0), kind=1)
    score, _, feas = run(gpu_free, node_aux, classes, task)
    assert np.all(feas == 0.0)
    assert np.all(score == NEG_INF_SCORE)


def test_cpu_power_delta_socket_boundary():
    """CPU-only task crossing a socket boundary must cost a socket
    promotion on the fuller node — PWR then prefers the node whose
    ceiling doesn't move."""
    gpu_free = np.full((2, 1), -1.0, dtype=np.float32)
    node_aux = np.array(
        [
            # 30/96 vCPU used: +4 stays within ceil(34/32)=2? no: ceil(30/32)=1 -> ceil(34/32)=2 (promotes)
            [66.0, 1e6, 30.0, -1.0, 0.0, 0.0],
            # 2/96 used: ceil(2/32)=1 -> ceil(6/32)=1 (no promotion)
            [94.0, 1e6, 2.0, -1.0, 0.0, 0.0],
        ],
        dtype=np.float32,
    )
    classes = make_classes(np.random.default_rng(0), m=4)
    task = np.zeros(8, dtype=np.float32)
    task[0], task[1], task[6] = 4.0, 0.0, -1.0
    score, _, feas = run(gpu_free, node_aux, classes, task, alpha=np.array([1.0], np.float32))
    assert feas.tolist() == [1.0, 1.0]
    assert np.argmax(score) == 1


def test_scores_are_finite_everywhere():
    rng = np.random.default_rng(11)
    for kind in (0, 1, 2):
        gpu_free, node_aux = make_cluster(rng, n=32, g=8)
        classes = make_classes(rng, m=32)
        task = make_task(rng, kind=kind)
        score, best_gpu, feas = run(gpu_free, node_aux, classes, task)
        assert np.all(np.isfinite(score))
        assert np.all(np.isfinite(best_gpu))
        assert set(np.unique(feas)).issubset({0.0, 1.0})
        assert not math.isnan(float(score.sum()))
