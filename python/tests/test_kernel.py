"""L1 correctness: the Pallas fragmentation kernel vs the pure-jnp
oracle (`ref.py`) — the core correctness signal of the compile path —
plus hand-computed fragmentation cases from the paper's definitions."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import frag_pass_ref
from compile.kernels.score import f_node, frag_pass

from tests.helpers import make_classes, make_cluster, make_task

RTOL, ATOL = 1e-5, 1e-5


def run_both(gpu_free, node_aux, classes, task, block_n=32):
    got = frag_pass(gpu_free, node_aux, classes, task, block_n=block_n)
    want = frag_pass_ref(gpu_free, node_aux, classes, task)
    return got, want


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("task_kind", [0, 1, 2])
def test_kernel_matches_ref_random(seed, task_kind):
    rng = np.random.default_rng(seed)
    gpu_free, node_aux = make_cluster(rng, n=64, g=8)
    classes = make_classes(rng, m=32)
    task = make_task(rng, kind=task_kind)
    got, want = run_both(gpu_free, node_aux, classes, task)
    for g, w, name in zip(got, want, ["before", "after_frac", "after_alt"]):
        np.testing.assert_allclose(g, w, rtol=RTOL, atol=ATOL, err_msg=name)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_blocks=st.integers(1, 4),
    g=st.integers(1, 8),
    m=st.integers(1, 32),
    task_kind=st.integers(0, 2),
)
def test_kernel_matches_ref_hypothesis(seed, n_blocks, g, m, task_kind):
    """Shape/value sweep: any (N, G, M) combination must agree."""
    rng = np.random.default_rng(seed)
    block_n = 16
    n = block_n * n_blocks
    gpu_free, node_aux = make_cluster(rng, n=n, g=g, n_real=max(1, n - 3))
    classes = make_classes(rng, m=m)
    task = make_task(rng, kind=task_kind)
    got, want = run_both(gpu_free, node_aux, classes, task, block_n=block_n)
    for gg, w in zip(got, want):
        np.testing.assert_allclose(gg, w, rtol=RTOL, atol=ATOL)


def encode_node(cpu_free, mem_free, model, free, g=4):
    gpu_free = np.full((1, g), -1.0, dtype=np.float32)
    gpu_free[0, : len(free)] = free
    aux = np.array([[cpu_free, mem_free, 0.0, model, 30.0, 150.0]], dtype=np.float32)
    return gpu_free, aux


def fclass(cpu, units, isfrac, iswhole, pop, constr=-1.0):
    return np.array(
        [[cpu, 0.0, units, isfrac, iswhole, pop, constr]], dtype=np.float32
    )


def f_node_np(gpu_free, aux, classes):
    return np.asarray(
        f_node(aux[:, 0], aux[:, 1], aux[:, 3], gpu_free, classes)
    )


class TestFragmentationDefinitions:
    """Hand-checked cases of F_n(m) (paper §II / FGD's two cases)."""

    def test_case1_infeasible_all_fragments(self):
        # Node with no CPU left: a 1-vCPU class cannot run => all free
        # GPU resources fragment.
        gpu_free, aux = encode_node(0.0, 1e6, 5, [1.0, 0.5])
        classes = fclass(1.0, 0.5, 1.0, 0.0, 1.0)
        assert f_node_np(gpu_free, aux, classes)[0] == pytest.approx(1.5)

    def test_case2_fractional_small_residuals(self):
        # Residuals 0.3 and 0.6; class wants 0.5 => only 0.3 fragments.
        gpu_free, aux = encode_node(96.0, 1e6, 5, [0.3, 0.6, 1.0])
        classes = fclass(1.0, 0.5, 1.0, 0.0, 1.0)
        assert f_node_np(gpu_free, aux, classes)[0] == pytest.approx(0.3)

    def test_case2_whole_counts_partials(self):
        gpu_free, aux = encode_node(96.0, 1e6, 5, [0.3, 0.6, 1.0])
        classes = fclass(1.0, 1.0, 0.0, 1.0, 1.0)
        assert f_node_np(gpu_free, aux, classes)[0] == pytest.approx(0.9)

    def test_cpu_only_class_no_frag_when_feasible(self):
        gpu_free, aux = encode_node(96.0, 1e6, 5, [0.3, 0.6])
        classes = fclass(1.0, 0.0, 0.0, 0.0, 1.0)
        assert f_node_np(gpu_free, aux, classes)[0] == pytest.approx(0.0)

    def test_constraint_mismatch_is_case1(self):
        # Class pinned to model 3 (T4) on a model-5 (G2) node.
        gpu_free, aux = encode_node(96.0, 1e6, 5, [1.0, 1.0])
        classes = fclass(1.0, 1.0, 0.0, 1.0, 1.0, constr=3.0)
        assert f_node_np(gpu_free, aux, classes)[0] == pytest.approx(2.0)

    def test_popularity_weighting(self):
        gpu_free, aux = encode_node(96.0, 1e6, 5, [0.2, 1.0])
        classes = np.concatenate(
            [
                fclass(1.0, 0.5, 1.0, 0.0, 0.5),  # frag 0.2
                fclass(1.0, 1.0, 0.0, 1.0, 0.5),  # frag 0.2
            ]
        )
        assert f_node_np(gpu_free, aux, classes)[0] == pytest.approx(0.2)

    def test_padding_gpus_ignored(self):
        a = encode_node(96.0, 1e6, 5, [0.5], g=2)
        b = encode_node(96.0, 1e6, 5, [0.5], g=8)
        classes = fclass(1.0, 1.0, 0.0, 1.0, 1.0)
        assert f_node_np(*a, classes)[0] == pytest.approx(
            f_node_np(*b, classes)[0]
        )


class TestHypotheticalPlacements:
    def test_frac_placement_reduces_target_gpu(self):
        rng = np.random.default_rng(0)
        gpu_free, aux = encode_node(96.0, 1e6, 5, [1.0, 0.5, 0.25], g=4)
        classes = make_classes(rng, m=8)
        task = np.array([2.0, 0.0, 0.5, 1.0, 0.0, 0.0, -1.0, 0.0], dtype=np.float32)
        fb, fa_frac, _ = frag_pass_ref(gpu_free, aux, classes, task)
        # Placing 0.5 on GPU1 (0.5 free) empties it: recompute by hand.
        gpu_after, aux_after = encode_node(94.0, 1e6 - 0.0, 5, [1.0, 0.0, 0.25], g=4)
        want = f_node_np(gpu_after, aux_after, classes)[0]
        assert fa_frac[0, 1] == pytest.approx(want, rel=1e-5)

    def test_whole_placement_takes_lowest_free(self):
        rng = np.random.default_rng(1)
        gpu_free, aux = encode_node(96.0, 1e6, 5, [0.5, 1.0, 1.0, 1.0], g=4)
        classes = make_classes(rng, m=8)
        task = np.array([2.0, 0.0, 2.0, 0.0, 1.0, 2.0, -1.0, 0.0], dtype=np.float32)
        _, _, fa_alt = frag_pass_ref(gpu_free, aux, classes, task)
        gpu_after, aux_after = encode_node(94.0, 1e6, 5, [0.5, 0.0, 0.0, 1.0], g=4)
        want = f_node_np(gpu_after, aux_after, classes)[0]
        assert fa_alt[0] == pytest.approx(want, rel=1e-5)

    def test_cpu_only_keeps_gpus(self):
        rng = np.random.default_rng(2)
        gpu_free, aux = encode_node(96.0, 1e6, 5, [0.5, 1.0], g=4)
        classes = make_classes(rng, m=8)
        task = np.array([32.0, 0.0, 0.0, 0.0, 0.0, 0.0, -1.0, 0.0], dtype=np.float32)
        _, _, fa_alt = frag_pass_ref(gpu_free, aux, classes, task)
        gpu_after, aux_after = encode_node(64.0, 1e6, 5, [0.5, 1.0], g=4)
        want = f_node_np(gpu_after, aux_after, classes)[0]
        assert fa_alt[0] == pytest.approx(want, rel=1e-5)
