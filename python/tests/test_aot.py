"""AOT path: lowering the scoring graph to HLO text and executing the
text through jax's own XLA client must reproduce the jit outputs —
the same text the Rust PJRT runtime loads."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.model import make_scorer
from tests.helpers import make_classes, make_cluster, make_task


@pytest.fixture(scope="module")
def small_hlo(tmp_path_factory):
    root = tmp_path_factory.mktemp("artifacts")
    # Tiny variant for test speed (the real build uses aot.VARIANTS).
    aot.VARIANTS_SAVED = aot.VARIANTS
    text = aot.lower_variant(n=32, g=4, m=8, block_n=16)
    path = root / "scorer.hlo.txt"
    path.write_text(text)
    return str(path), text


def test_hlo_text_structure(small_hlo):
    _, text = small_hlo
    assert "HloModule" in text
    assert "f32[32,4]" in text  # gpu_free param shape
    # No TPU custom-calls: interpret-mode pallas lowers to plain HLO.
    assert "mosaic" not in text.lower()


def test_build_writes_meta(tmp_path):
    files = aot.build(str(tmp_path), variants=["small"])
    assert any(f.endswith("scorer.hlo.txt") for f in files)
    meta_file = [f for f in files if f.endswith("scorer_meta.json")][0]
    meta = json.load(open(meta_file))
    assert meta == {"n": 64, "g": 8, "m": 64}


def test_hlo_text_parses_back(small_hlo):
    """The emitted text must re-parse with XLA's HLO parser — the same
    parser the Rust runtime uses (`HloModuleProto::from_text_file`).
    Full load-and-execute parity is asserted by the Rust integration
    test `tests/scorer_parity.rs` and `repro scorer-check`."""
    from jax._src.lib import xla_client as xc

    _, text = small_hlo
    mod = xc._xla.hlo_module_from_text(text)
    # Round-trip: proto ids got reassigned, shapes preserved.
    text2 = mod.to_string()
    assert "f32[32,4]" in text2


def test_lowered_compile_matches_eager(small_hlo):
    """`jax.jit(...).lower(...).compile()` (the artifact's computation)
    must equal the eager scorer on random inputs."""
    import jax

    n, g, m = 32, 4, 8
    rng = np.random.default_rng(5)
    gpu_free, node_aux = make_cluster(rng, n=n, g=g)
    classes = make_classes(rng, m=m)
    task = make_task(rng, kind=1)
    alpha = np.array([0.1], dtype=np.float32)

    scorer = make_scorer(n, g, m, use_pallas=True, block_n=16)
    want = [np.asarray(x) for x in scorer(gpu_free, node_aux, classes, task, alpha)]
    compiled = jax.jit(scorer).lower(gpu_free, node_aux, classes, task, alpha).compile()
    got = [np.asarray(x) for x in compiled(gpu_free, node_aux, classes, task, alpha)]
    for w, g_ in zip(want, got):
        np.testing.assert_allclose(w, g_, rtol=1e-5, atol=1e-4)
