"""Shared generators for the python test-suite: random dense-encoded
cluster states, class tables and tasks matching the contract in
rust/src/runtime/scorer.rs."""

import numpy as np

# GPU models as (index, p_idle, p_max) — Table II.
GPU_MODELS = [
    (0, 30.0, 300.0),  # V100M16
    (1, 30.0, 300.0),  # V100M32
    (2, 25.0, 250.0),  # P100
    (3, 10.0, 70.0),   # T4
    (4, 30.0, 150.0),  # A10
    (5, 30.0, 150.0),  # G2
    (6, 50.0, 400.0),  # G3
]

FRACTIONS = np.array([0.0, 0.1, 0.2, 0.25, 0.3, 0.4, 0.5, 0.6, 0.75, 0.8, 0.9, 1.0])


def make_cluster(rng, n, g, n_real=None, cpu_only_frac=0.2):
    """Random (gpu_free [n,g], node_aux [n,6]) encoding."""
    n_real = n if n_real is None else n_real
    gpu_free = np.full((n, g), -1.0, dtype=np.float32)
    node_aux = np.zeros((n, 6), dtype=np.float32)
    node_aux[n_real:, 0] = -1.0  # padding slots
    for i in range(n_real):
        cpu_total = float(rng.choice([64.0, 94.0, 96.0, 128.0]))
        cpu_alloc = float(rng.choice(np.arange(0, cpu_total + 1, 2.0)))
        mem_total = 262144.0
        mem_alloc = float(rng.uniform(0, mem_total * 0.8))
        if rng.random() < cpu_only_frac:
            model = (-1, 0.0, 0.0)
            ngpus = 0
        else:
            model = GPU_MODELS[rng.integers(len(GPU_MODELS))]
            ngpus = int(rng.integers(1, g + 1))
            alloc = rng.choice(FRACTIONS, size=ngpus)
            gpu_free[i, :ngpus] = (1.0 - alloc).astype(np.float32)
        node_aux[i] = [
            cpu_total - cpu_alloc,
            mem_total - mem_alloc,
            cpu_alloc,
            float(model[0]),
            model[1],
            model[2],
        ]
    return gpu_free, node_aux


def make_classes(rng, m, m_real=None):
    """Random class table [m, 7] with popularity summing to 1."""
    m_real = m if m_real is None else m_real
    classes = np.zeros((m, 7), dtype=np.float32)
    pops = rng.random(m_real) + 0.05
    pops /= pops.sum()
    for j in range(m_real):
        kind = rng.integers(3)  # 0 cpu-only, 1 frac, 2 whole
        cpu = float(rng.choice([1.0, 2.0, 4.0, 8.0, 16.0]))
        mem = cpu * 3072.0
        if kind == 0:
            units, isfrac, iswhole = 0.0, 0.0, 0.0
        elif kind == 1:
            units = float(rng.choice(FRACTIONS[1:-1]))
            isfrac, iswhole = 1.0, 0.0
        else:
            units = float(rng.choice([1.0, 2.0, 4.0, 8.0]))
            isfrac, iswhole = 0.0, 1.0
        constr = float(rng.integers(7)) if rng.random() < 0.15 and units > 0 else -1.0
        classes[j] = [cpu, mem, units, isfrac, iswhole, pops[j], constr]
    return classes


def make_task(rng, kind=None):
    """Random task encoding [8]."""
    kind = int(rng.integers(3)) if kind is None else kind
    cpu = float(rng.choice([1.0, 2.0, 4.0, 8.0, 16.0]))
    mem = cpu * 3072.0
    task = np.zeros(8, dtype=np.float32)
    task[0], task[1] = cpu, mem
    task[6] = -1.0
    if kind == 1:  # fractional
        task[2] = float(rng.choice(FRACTIONS[1:-1]))
        task[3] = 1.0
    elif kind == 2:  # whole
        k = float(rng.choice([1.0, 2.0, 4.0, 8.0]))
        task[2] = k
        task[4] = 1.0
        task[5] = k
        if rng.random() < 0.2:
            task[6] = float(rng.integers(7))
    return task
