"""AOT lowering: JAX scoring graph → HLO **text** artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client. HLO text — *not* ``.serialize()`` — is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (per size variant)::

    artifacts/<variant>/scorer.hlo.txt     the compiled scoring graph
    artifacts/<variant>/scorer_meta.json   {"n":..,"g":..,"m":..,"mig":..}

Variants: ``small`` (N=64 — integration tests, benches) and ``full``
(N=1280 ≥ the paper's 1,213 nodes). Both now lower the MIG-aware
encoding (task slot 7 = 1 + MigProfile index for slice demands);
``"mig": true`` in the meta is how the Rust loader detects it — legacy
artifacts without the key keep the native-fallback path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import make_scorer

VARIANTS = {
    "small": dict(n=64, g=8, m=64, block_n=32, mig=True),
    "full": dict(n=1280, g=8, m=64, block_n=32, mig=True),
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    Rust side unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(n: int, g: int, m: int, block_n: int, use_pallas: bool = True, mig: bool = False):
    """Lower one artifact variant; returns the HLO text."""
    scorer = make_scorer(n, g, m, use_pallas=use_pallas, block_n=block_n, mig=mig)
    f32 = jnp.float32
    specs = (
        jax.ShapeDtypeStruct((n, g), f32),  # gpu_free
        jax.ShapeDtypeStruct((n, 6), f32),  # node_aux
        jax.ShapeDtypeStruct((m, 7), f32),  # classes
        jax.ShapeDtypeStruct((8,), f32),    # task
        jax.ShapeDtypeStruct((1,), f32),    # alpha
    )
    return to_hlo_text(jax.jit(scorer).lower(*specs))


def build(out_root: str, variants=None) -> list:
    written = []
    for name, cfg in VARIANTS.items():
        if variants and name not in variants:
            continue
        out_dir = os.path.join(out_root, name)
        os.makedirs(out_dir, exist_ok=True)
        text = lower_variant(
            cfg["n"], cfg["g"], cfg["m"], cfg["block_n"], mig=cfg.get("mig", False)
        )
        hlo_path = os.path.join(out_dir, "scorer.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(text)
        meta_path = os.path.join(out_dir, "scorer_meta.json")
        with open(meta_path, "w") as f:
            json.dump(
                {
                    "n": cfg["n"],
                    "g": cfg["g"],
                    "m": cfg["m"],
                    "mig": bool(cfg.get("mig", False)),
                },
                f,
            )
        print(f"wrote {hlo_path} ({len(text)} chars) + {meta_path}")
        written.extend([hlo_path, meta_path])
    return written


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact root dir")
    ap.add_argument("--variant", action="append", help="subset of variants")
    args = ap.parse_args()
    build(args.out, args.variant)


if __name__ == "__main__":
    main()
