"""L2 — the JAX scoring graph (Algorithm 1 + §IV-A, batched over nodes).

``make_scorer(n, g, m)`` builds the full PWR⊕FGD scoring function:

  filter (Cond. 1–3 + constraint)
    → PWR score  (−Δ estimated node power, Eq. 1–2)
    → FGD score  (−Δ expected fragmentation, via the L1 Pallas kernel)
    → k8s NormalizeScore (min-max → [0, 100] over feasible nodes)
    → weighted combine  α·PWR + (1−α)·FGD
    → per-node best GPU placement (the bind step).

The function is pure and jit-able; `aot.py` lowers it once to HLO text
that the Rust runtime (rust/src/runtime/scorer.rs) executes via PJRT on
every scheduling decision — Python never runs at serving time.

Encoding contract: rust/src/runtime/scorer.rs (kept in lock-step; the
Rust integration test `scorer_parity` enforces it end-to-end).
"""

import functools

import jax.numpy as jnp

from compile.kernels.score import EPS, frag_pass
from compile.kernels.ref import frag_pass_ref

# CPU power-model constants baked into the artifact (Xeon E5-2682 v4,
# paper §V-B): 2 vCPU per physical core × 16 cores.
VCPU_PER_SOCKET = 32.0
CPU_P_MAX = 120.0
CPU_P_IDLE = 15.0

# Sentinel for infeasible nodes (mirrored in scorer.rs).
NEG_INF_SCORE = -1.0e9


def _normalize_k8s(raw, feas):
    """k8s NormalizeScore: min-max → [0, 100] over feasible entries,
    **rounded to integers** (framework scores are int64); all-equal maps
    to 100 (matches rust normalize_scores)."""
    big = 1.0e30
    lo = jnp.min(jnp.where(feas, raw, big))
    hi = jnp.max(jnp.where(feas, raw, -big))
    spread = hi - lo
    flat = spread < 1e-12
    safe = jnp.where(flat, 1.0, spread)
    return jnp.where(flat, 100.0, jnp.round(100.0 * (raw - lo) / safe))


def score_cluster(
    gpu_free, node_aux, classes, task, alpha, *, use_pallas=True, block_n=32, mig=False
):
    """Score every node for one task. See module docstring.

    With ``mig=True`` (MIG-aware artifacts; ``"mig": true`` in the
    meta), task slot 7 carries ``1 + MigProfile index`` for slice
    demands. A slice demand scores like a fractional demand of its
    slice fraction — per-GPU free capacity is the dense relaxation of
    the occupancy mask; the Rust decode reconstructs the concrete legal
    window first-fit from the real masks. ``mig=False`` lowers the
    exact legacy graph (slot 7 is always 0 there).

    Returns (score [N], best_gpu [N], feasible [N]) — all f32.
    """
    if mig:
        is_mig = task[7] > 0.5
        task = task.at[3].set(jnp.where(is_mig, 1.0, task[3]))
    cpu_free = node_aux[:, 0]
    mem_free = node_aux[:, 1]
    cpu_alloc = node_aux[:, 2]
    model = node_aux[:, 3]
    gpu_p_idle = node_aux[:, 4]
    gpu_p_max = node_aux[:, 5]
    alpha = alpha[0]

    t_cpu, t_mem, t_units = task[0], task[1], task[2]
    t_isfrac, t_iswhole, t_k, t_constr = task[3], task[4], task[5], task[6]

    valid_node = cpu_free >= 0.0
    valid_gpu = gpu_free >= 0.0

    # ---- Filter: node feasibility (Cond. 1–3 + constraint). ----
    cpu_ok = t_cpu <= cpu_free + EPS
    mem_ok = t_mem <= mem_free + EPS
    has_gpu = model >= 0.0
    constr_ok = (t_constr < 0.0) | (jnp.abs(model - t_constr) < 0.5)
    maxfree = jnp.max(jnp.where(valid_gpu, gpu_free, -1.0), axis=-1)
    nfull = jnp.sum(jnp.where((gpu_free >= 1.0 - EPS) & valid_gpu, 1.0, 0.0), axis=-1)
    gpu_ok = jnp.where(t_isfrac > 0.0, maxfree >= t_units - EPS, nfull >= t_units - EPS)
    needs_gpu = t_units > 0.0
    feas = valid_node & cpu_ok & mem_ok & jnp.where(needs_gpu, has_gpu & constr_ok & gpu_ok, True)

    # ---- L1: fragmentation tensors. ----
    frag_impl = functools.partial(frag_pass, block_n=block_n) if use_pallas else frag_pass_ref
    fb, fa_frac, fa_alt = frag_impl(gpu_free, node_aux, classes, task)

    # ---- PWR: power delta (Eq. 1–2). ----
    cpu_delta = CPU_P_MAX * (
        jnp.ceil((cpu_alloc + t_cpu) / VCPU_PER_SOCKET) - jnp.ceil(cpu_alloc / VCPU_PER_SOCKET)
    ) + CPU_P_IDLE * (
        jnp.floor((cpu_free - t_cpu) / VCPU_PER_SOCKET) - jnp.floor(cpu_free / VCPU_PER_SOCKET)
    )
    gpu_wake = gpu_p_max - gpu_p_idle  # idle → p_max promotion per GPU

    # Fractional placements: per-GPU feasibility and deltas.
    pf = valid_gpu & (gpu_free >= t_units - EPS)  # [N, G]
    dp_frac = jnp.where(gpu_free >= 1.0 - EPS, gpu_wake[:, None], 0.0)  # [N, G]
    df_frac = fa_frac - fb[:, None]  # [N, G]
    big = 1.0e30
    dp_frac_best = jnp.min(jnp.where(pf, dp_frac, big), axis=-1)
    df_frac_best = jnp.min(jnp.where(pf, df_frac, big), axis=-1)

    # Whole-GPU / CPU-only placement deltas.
    dp_alt = jnp.where(t_iswhole > 0.0, t_k * gpu_wake, 0.0)
    df_alt = fa_alt - fb

    dp_node = jnp.where(t_isfrac > 0.0, dp_frac_best, dp_alt)
    df_node = jnp.where(t_isfrac > 0.0, df_frac_best, df_alt)

    # ---- NormalizeScore + combine (§IV-A). ----
    pwr_raw = -(cpu_delta + dp_node)
    fgd_raw = -df_node
    pwr_norm = _normalize_k8s(pwr_raw, feas)
    fgd_norm = _normalize_k8s(fgd_raw, feas)
    score = alpha * pwr_norm + (1.0 - alpha) * fgd_norm
    score = jnp.where(feas, score, NEG_INF_SCORE)

    # ---- Bind: best GPU inside each node (fractional tasks). ----
    def _norm_per_node(v):  # min-max over feasible placements, per node
        lo = jnp.min(jnp.where(pf, v, big), axis=-1, keepdims=True)
        hi = jnp.max(jnp.where(pf, v, -big), axis=-1, keepdims=True)
        spread = hi - lo
        flat = spread < 1e-12
        return jnp.where(flat, 0.0, (v - lo) / jnp.where(flat, 1.0, spread))

    cost = alpha * _norm_per_node(dp_frac) + (1.0 - alpha) * _norm_per_node(df_frac)
    cost = jnp.where(pf, cost, big)
    best_gpu = jnp.argmin(cost, axis=-1).astype(jnp.float32)  # first min = lowest idx
    best_gpu = jnp.where((t_isfrac > 0.0) & feas, best_gpu, -1.0)

    return score, best_gpu, jnp.where(feas, 1.0, 0.0)


def make_scorer(n, g, m, *, use_pallas=True, block_n=32, mig=False):
    """Bind static shapes; returns `f(gpu_free, node_aux, classes, task,
    alpha)` ready for `jax.jit(...).lower(...)`."""
    del n, g, m  # shapes are carried by the example args at lower time

    def scorer(gpu_free, node_aux, classes, task, alpha):
        return score_cluster(
            gpu_free, node_aux, classes, task, alpha,
            use_pallas=use_pallas, block_n=block_n, mig=mig,
        )

    return scorer
