"""Pure-jnp oracle for the L1 fragmentation kernel.

Implements exactly the semantics of ``score.frag_pass`` without Pallas
(straight broadcast jnp). pytest (`python/tests/test_kernel.py`)
hypothesis-sweeps random cluster states, tasks and class tables and
asserts the kernel matches this reference to f32 tolerance; the L2 model
can also be built on this implementation (``use_pallas=False``) as an
A/B oracle for the full scoring graph.
"""

import jax.numpy as jnp

from compile.kernels.score import EPS, f_node


def frag_pass_ref(gpu_free, node_aux, classes, task):
    """Reference implementation of ``score.frag_pass`` (same contract)."""
    cpu_free = node_aux[:, 0]
    mem_free = node_aux[:, 1]
    model = node_aux[:, 3]
    g = gpu_free.shape[-1]

    frag_before = f_node(cpu_free, mem_free, model, gpu_free, classes)

    t_cpu, t_mem, t_units = task[0], task[1], task[2]
    t_iswhole, t_k = task[4], task[5]
    cpu_after = cpu_free - t_cpu
    mem_after = mem_free - t_mem

    eye = jnp.eye(g, dtype=gpu_free.dtype)
    free_var = gpu_free[:, None, :] - t_units * eye[None, :, :]
    free_var = jnp.where((free_var < 0.0) & (free_var > -1e-3), 0.0, free_var)
    frag_after_frac = f_node(
        cpu_after[:, None], mem_after[:, None], model[:, None], free_var, classes
    )

    is_free = jnp.where(gpu_free >= 1.0 - EPS, 1.0, 0.0)
    takeable = jnp.cumsum(is_free, axis=-1) <= t_k
    take = (is_free > 0.0) & takeable & (t_iswhole > 0.0)
    free_alt = jnp.where(take, 0.0, gpu_free)
    frag_after_alt = f_node(cpu_after, mem_after, model, free_alt, classes)

    return frag_before, frag_after_frac, frag_after_alt
