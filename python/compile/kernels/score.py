"""L1 — the Pallas fragmentation-scoring kernel.

The scheduler's numeric hot-spot is the per-decision evaluation of the
FGD expected-fragmentation metric over *every* node, *every* candidate
GPU placement and *every* workload class: an ``[N, G, M]`` reduction
(paper §II; Weng et al. ATC'23). This kernel computes, for one task and
the dense-encoded cluster state:

* ``frag_before[n]``      — ``F_n(M)`` of the current state,
* ``frag_after_frac[n,g]`` — ``F_n(M)`` after hypothetically placing a
  fractional task on GPU ``g`` (garbage where the placement is
  infeasible; L2 masks it),
* ``frag_after_alt[n]``   — ``F_n(M)`` after the canonical whole-GPU
  placement (k lowest-indexed fully-free GPUs) for whole-GPU tasks, or
  after the CPU/MEM-only update for CPU-only tasks.

TPU mapping (DESIGN.md §Hardware-Adaptation): the node axis is tiled
into VMEM-sized blocks via ``BlockSpec`` — each block holds the
``[BLOCK_N, G]`` GPU state plus the full ``[M, 7]`` class table resident
in VMEM, and the ``[BLOCK_N, G, M]`` broadcast reduction feeds the VPU.
``interpret=True`` is mandatory here: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO
that the Rust runtime executes AOT.

Encoding contract: see ``rust/src/runtime/scorer.rs`` (the Rust side is
the source of truth; `python/tests/test_model.py` cross-checks it).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# f32 comparison slack (mirrors EPS in rust/src/cluster/node.rs, widened
# for single precision).
EPS = 1e-6

# Default node-block size: [BLOCK_N, G, M] f32 intermediates stay well
# under TPU VMEM (32·8·32·4 B = 32 KiB per broadcast array; the kernel
# materializes ~6 of them plus the [BLOCK_N, G, G] placement variants).
BLOCK_N = 32


def f_node(cpu_free, mem_free, model, free, classes):
    """Expected fragmentation ``F_n(M)`` for a batch of node states.

    Args:
      cpu_free:  [...]        free vCPUs (−1 ⇒ padding node slot).
      mem_free:  [...]        free memory (MiB).
      model:     [...]        GPU model index (−1 ⇒ CPU-only node).
      free:      [..., G]     per-GPU free fraction (−1 ⇒ padding GPU).
      classes:   [M, 7]       [cpu, mem, units, is_frac, is_whole, pop,
                               constraint_idx].

    Returns: [...] — ``Σ_m pop_m · F_n(m)`` (paper Eq. 4 per node).
    """
    valid = free >= 0.0
    freec = jnp.where(valid, free, 0.0)

    c_cpu = classes[:, 0]
    c_mem = classes[:, 1]
    c_units = classes[:, 2]
    c_isfrac = classes[:, 3]
    c_iswhole = classes[:, 4]
    c_pop = classes[:, 5]
    c_constr = classes[:, 6]

    # Node-level reductions over the GPU axis.
    maxfree = jnp.max(jnp.where(valid, free, -1.0), axis=-1)
    nfull = jnp.sum(jnp.where((free >= 1.0 - EPS) & valid, 1.0, 0.0), axis=-1)
    sumfree = jnp.sum(freec, axis=-1)  # case 1: everything is a fragment
    # case 2 for whole-GPU classes: all partial residuals fragment.
    partials = jnp.sum(
        jnp.where((freec > EPS) & (freec < 1.0 - EPS), freec, 0.0), axis=-1
    )

    bx = lambda a: a[..., None]  # append the class axis

    # Feasibility of class m on the node (Cond. 1–3 + constraint).
    cpu_ok = bx(cpu_free) + EPS >= c_cpu
    mem_ok = bx(mem_free) + EPS >= c_mem
    has_gpu = bx(model) >= 0.0
    constr_ok = (c_constr < 0.0) | (jnp.abs(bx(model) - c_constr) < 0.5)
    frac_ok = bx(maxfree) >= c_units - EPS
    whole_ok = bx(nfull) >= c_units - EPS
    gpu_ok = jnp.where(c_isfrac > 0.0, frac_ok, whole_ok)
    needs_gpu = c_units > 0.0
    feas = cpu_ok & mem_ok & jnp.where(needs_gpu, has_gpu & constr_ok & gpu_ok, True)

    # case 2 for fractional classes: residuals too small for d_m.
    f_gm = freec[..., :, None]  # [..., G, M]
    case2_frac = jnp.sum(
        jnp.where((f_gm > EPS) & (f_gm < c_units - EPS), f_gm, 0.0), axis=-2
    )
    case2 = c_isfrac * case2_frac + c_iswhole * bx(partials)
    frag_m = jnp.where(feas, case2, bx(sumfree))
    return jnp.sum(c_pop * frag_m, axis=-1)


def _score_kernel(gpu_free_ref, aux_ref, classes_ref, task_ref, fb_ref, fa_frac_ref, fa_alt_ref):
    """Pallas kernel body for one node block."""
    free = gpu_free_ref[...]  # [Bn, G]
    aux = aux_ref[...]  # [Bn, 6]
    classes = classes_ref[...]  # [M, 7]
    task = task_ref[...]  # [8]

    cpu_free = aux[:, 0]
    mem_free = aux[:, 1]
    model = aux[:, 3]
    g = free.shape[-1]

    # F_n(M) of the current state.
    fb_ref[...] = f_node(cpu_free, mem_free, model, free, classes)

    t_cpu, t_mem, t_units = task[0], task[1], task[2]
    t_iswhole, t_k = task[4], task[5]
    cpu_after = cpu_free - t_cpu
    mem_after = mem_free - t_mem

    # Fractional placement variants: state with GPU v reduced by d.
    eye = jnp.eye(g, dtype=free.dtype)
    free_var = free[:, None, :] - t_units * eye[None, :, :]
    # Clamp the (feasible) modified entry's f32 underflow to 0; genuinely
    # negative entries belong to infeasible placements L2 masks out.
    free_var = jnp.where((free_var < 0.0) & (free_var > -1e-3), 0.0, free_var)
    fa_frac_ref[...] = f_node(
        cpu_after[:, None], mem_after[:, None], model[:, None], free_var, classes
    )

    # Alternative variant: whole-GPU task takes the k lowest-indexed
    # fully-free GPUs; CPU-only task leaves GPUs untouched.
    is_free = jnp.where(free >= 1.0 - EPS, 1.0, 0.0)
    takeable = jnp.cumsum(is_free, axis=-1) <= t_k
    take = (is_free > 0.0) & takeable & (t_iswhole > 0.0)
    free_alt = jnp.where(take, 0.0, free)
    fa_alt_ref[...] = f_node(cpu_after, mem_after, model, free_alt, classes)


@functools.partial(jax.jit, static_argnames=("block_n",))
def frag_pass(gpu_free, node_aux, classes, task, *, block_n=BLOCK_N):
    """Run the fragmentation kernel over the whole cluster encoding.

    Args:
      gpu_free: [N, G] f32, node_aux: [N, 6] f32, classes: [M, 7] f32,
      task: [8] f32. N must be a multiple of ``block_n``.

    Returns: (frag_before [N], frag_after_frac [N, G], frag_after_alt [N]).
    """
    n, g = gpu_free.shape
    m = classes.shape[0]
    assert n % block_n == 0, f"N={n} not a multiple of block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, g), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 6), lambda i: (i, 0)),
            pl.BlockSpec((m, 7), lambda i: (0, 0)),
            pl.BlockSpec((8,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, g), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n, g), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(gpu_free, node_aux, classes, task)
